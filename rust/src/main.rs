//! tinytrain — on-device training coordinator CLI (L3 leader).
//!
//! Subcommands:
//!   pretrain  --arch <a> [--episodes N] [--steps N] [--lr X]   offline meta-training
//!   search    --arch <a> [--population N] [--generations N]    SparseUpdate ES (offline)
//!   adapt     --arch <a> --domain <d> [--method M] [--steps N] one on-device adaptation
//!   grid      [--arch a] [--episodes N] [--workers K]          parallel analytic grid
//!   serve     [--tenants N] [--workers K] [--mode open|closed] multi-tenant service replay
//!             [--listen ADDR]                                  ... or HTTP service
//!   loadgen   --addr HOST:PORT [--connections N] [--shutdown]  wire replay + bit-identity
//!   exp       <table1|table2|...|fig6b|all|all-analytic> [...] regenerate paper artefacts
//!   info      [--arch a,b,c]                                   artifact + arch summary
//!
//! Run with no args for this help. See DESIGN.md for the experiment index.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use tinytrain::coordinator::{
    meta_train, search, AdaptationSession, Backend, Method, ModelEngine, PretrainConfig,
    TrainConfig,
};
use tinytrain::data::{domain_by_name, Episode, Sampler};
use tinytrain::harness::{self, parallel};
use tinytrain::metrics::{fmt_kb, fmt_pct, fmt_us, Table};
use tinytrain::model::{ModelMeta, ParamStore};
use tinytrain::net;
use tinytrain::runtime::{ArtifactStore, Runtime};
use tinytrain::serve;
use tinytrain::util::cli::Args;
use tinytrain::util::pool::default_workers;
use tinytrain::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("pretrain") => pretrain(args),
        Some("search") => run_search(args),
        Some("adapt") => adapt(args),
        Some("grid") => grid(args),
        Some("serve") => match args.opt("listen") {
            Some(addr) => serve_listen(args, &addr),
            None => serve(args),
        },
        Some("loadgen") => loadgen(args),
        Some("exp") => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: tinytrain exp <id> — see DESIGN.md"))?;
            harness::run_experiment(id, args)
        }
        Some("info") => info(args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
tinytrain — TinyTrain (ICML 2024) on-device training coordinator

USAGE:
  tinytrain pretrain --arch mcunet [--episodes 60] [--steps 4] [--lr 0.003]
  tinytrain search   --arch mcunet [--population 8] [--generations 4]
  tinytrain adapt    --arch mcunet --domain traffic [--method tinytrain] [--steps 10]
                     [--backend auto|host|device|analytic]
  tinytrain grid     [--arch mcunet] [--episodes 4] [--steps 8] [--workers N]
                     [--domains a,b] [--seed S] [--no-render-cache]
                     (analytic backend, no PJRT needed)
  tinytrain serve    [--arch mcunet] [--tenants 8] [--domains a,b] [--episodes 4]
                     [--workers N] [--queue-cap 64] [--mode open|closed]
                     [--method M] [--steps 6] [--delta-budget-kb KB] [--seed S]
                     [--shards N] [--compact-depth 4] [--quantize off|FRAC]
                     [--faults SPEC]
                     (multi-tenant adaptation service: replays a synthetic
                      trace, reports throughput + latency percentiles, asserts
                      bit-identity against the sequential reference arm —
                      with --faults, through injected worker panics.
                      --shards 0 auto-sizes from the worker count;
                      --quantize FRAC keeps FRAC of the budget f32-hot and
                      demotes LRU-cold overlays to int8)
  tinytrain serve    --listen 127.0.0.1:0 [--acceptors N] [--verify-decode]
                     [--workers N] [--queue-cap 64] [--delta-budget-kb KB]
                     [--shards N] [--compact-depth 4] [--quantize off|FRAC]
                     [--faults SPEC] [--state-dir DIR] [--snapshot-every-s 5]
                     (HTTP front-end over the same service: POST /v1/episodes,
                      GET /v1/tickets/{id}, GET /v1/tenants/{id}/sync,
                      GET /v1/tenants/{id}/stats, GET /v1/stats,
                      GET /metrics, GET /healthz, POST /v1/shutdown;
                      --state-dir enables crash-safe snapshots + spill files)
  tinytrain loadgen  --addr HOST:PORT [--connections 4] [--mode open|closed]
                     [--tenants 8] [--domains a,b] [--episodes 4] [--steps 6]
                     [--seed S] [--no-verify] [--shutdown] [--faults SPEC]
                     [--deadline-ms MS] [--retry-attempts 8] [--retry-seed S]
                     [--from-ep A] [--to-ep B] [--verify-full-trace]
                     [--quant-slack S]
                     (replays the synthetic trace over real sockets and asserts
                      the wire results bit-identical to the in-process arm;
                      chaos client: retries sheds/drops/failures with seeded
                      backoff; --from/--to-ep slice episodes for split runs,
                      --verify-full-trace checks final deltas across a restart,
                      --quant-slack S loosens that check to S half-steps of the
                      int8 grid for a --quantize server)

Fault SPEC grammar: seed=U64,panic=P,slow=P[:MS],shed=P,drop=P — e.g.
`--faults \"seed=5,panic=0.2,slow=0.1:10,shed=0.2,drop=0.1\"`.
  tinytrain exp      <table1|table2|table3|table4|table5|table7|table8|table9|table10|
                      table11|fig1|fig3|fig4|fig5|fig6a|fig6b|all|all-analytic>
                     [--tier smoke|full|paper] [--arch a,b] [--episodes N] [--steps N]
  tinytrain info     [--arch mcunet,mbv2,proxyless]

Methods for `adapt --method`: none, fulltrain, lastlayer, tinytl,
sparseupdate, tinytrain (default).
";

fn load_engine(args: &Args) -> Result<(Runtime, ArtifactStore, ModelEngine)> {
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover(args.opt("artifacts"))?;
    let arch = args.str("arch", "mcunet");
    let engine = ModelEngine::load(&rt, &store, &arch)?;
    Ok((rt, store, engine))
}

/// Offline stage: meta-train on the source domain, save weights.
fn pretrain(args: &Args) -> Result<()> {
    let (_rt, _store, engine) = load_engine(args)?;
    let cfg = PretrainConfig {
        episodes: args.usize("episodes", 60),
        steps_per_episode: args.usize("steps", 4),
        lr: args.f64("lr", 3e-3) as f32,
        seed: args.u64("seed", 13),
        log_every: args.usize("log-every", 10),
    };
    eprintln!(
        "meta-training {} on source domain: {} episodes x {} steps",
        engine.meta.arch, cfg.episodes, cfg.steps_per_episode
    );
    let mut params = ParamStore::init(&engine.meta, args.u64("init-seed", 42));
    let t0 = std::time::Instant::now();
    meta_train(&engine, &mut params, &cfg, |m| eprintln!("{m}"))?;
    params.save(&engine.weights_path)?;
    eprintln!(
        "saved {} ({} params) in {:.1}s",
        engine.weights_path.display(),
        engine.meta.total_theta,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Offline SparseUpdate evolutionary search; saves the policy artifact.
fn run_search(args: &Args) -> Result<()> {
    let (_rt, store, engine) = load_engine(args)?;
    let params = ParamStore::load_or_init(&engine.meta, &engine.weights_path, 42);
    let cfg = search::SearchConfig {
        population: args.usize("population", 8),
        generations: args.usize("generations", 4),
        mem_budget: args.f64("mem-budget", 0.0),
        episodes_per_eval: args.usize("episodes-per-eval", 1),
        steps: args.usize("steps", 4),
        seed: args.u64("seed", 77),
    };
    eprintln!(
        "evolutionary search for {}: pop {} x gen {} (offline, server-side in the paper)",
        engine.meta.arch, cfg.population, cfg.generations
    );
    let t0 = std::time::Instant::now();
    let (policy, fitness) = search::evolutionary_search(&engine, &params, &cfg)?;
    let path = store.dir.join(format!("sparse_policy_{}.json", engine.meta.arch));
    search::save_policy(&path, &policy, fitness)?;
    eprintln!(
        "best policy ({} layers, fitness {:.3}) saved to {} in {:.0}s",
        policy.layer_ratios.len(),
        fitness,
        path.display(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// One on-device adaptation episode (demo of Algorithm 1).
fn adapt(args: &Args) -> Result<()> {
    let backend = parse_backend(&args.str("backend", "auto"))?;
    let store = ArtifactStore::discover(args.opt("artifacts"))?;
    let arch = args.str("arch", "mcunet");
    let domain_name = args.str("domain", "traffic");
    let domain =
        domain_by_name(&domain_name).ok_or_else(|| anyhow!("unknown domain {domain_name}"))?;
    let mut rng = Rng::new(args.u64("seed", 1));
    let tc = TrainConfig {
        steps: args.usize("steps", 10),
        lr: args.f64("lr", 6e-3) as f32,
        seed: 0, // per-episode seed passed to adapt_with_seed below
    };

    // The analytic backend is artifact-light: it needs only the metadata
    // JSON — no PJRT client, no compiled graphs — so don't build either.
    if backend == Backend::Analytic {
        let arts = store.model(&arch);
        let meta = ModelMeta::load(&arts.meta)?;
        let params = ParamStore::load_or_init(&meta, &arts.weights, 42);
        let method = parse_method(&args.str("method", "tinytrain"), Some(&store), &meta)?;
        let ep = Sampler::new(domain.as_ref(), &meta.shapes).sample(&mut rng);
        announce_episode(&meta.arch, &domain_name, &ep);
        let session = AdaptationSession::analytic(&meta).method(method).config(tc).build()?;
        return report_episode(session.adapt_with_seed(&params, &ep, rng.next_u64())?);
    }

    let rt = Runtime::cpu()?;
    let engine = ModelEngine::load(&rt, &store, &arch)?;
    let params = ParamStore::load_or_init(&engine.meta, &engine.weights_path, 42);
    let method = parse_method(&args.str("method", "tinytrain"), Some(&store), &engine.meta)?;
    let ep = Sampler::new(domain.as_ref(), &engine.meta.shapes).sample(&mut rng);
    announce_episode(&engine.meta.arch, &domain_name, &ep);
    let session = AdaptationSession::builder(&engine)
        .method(method)
        .config(tc)
        .backend(backend)
        .build()?;
    report_episode(session.adapt_with_seed(&params, &ep, rng.next_u64())?)
}

/// Parallel analytic accuracy grid: (method × domain × episode) cells
/// fanned out across a scoped thread pool with per-thread sessions —
/// the multi-tenant serving shape, runnable without PJRT. Falls back to
/// the synthetic architecture when no artifacts are deployed, so the
/// command works in any checkout.
fn grid(args: &Args) -> Result<()> {
    let (meta, params) = analytic_model(args, "grid")?;
    let cfg = parallel::GridConfig {
        episodes: args.usize("episodes", 4),
        steps: args.usize("steps", 8),
        lr: args.f64("lr", 6e-3) as f32,
        seed: args.u64("seed", 7),
        workers: args.usize("workers", default_workers()),
        // Output is bit-identical with the cache on or off; the flag
        // exists for A/B timing runs.
        render_cache: !args.bool("no-render-cache"),
    };
    let domains = args.list("domains", &tinytrain::data::DOMAIN_NAMES);
    let methods = vec![
        Method::None,
        Method::LastLayer,
        Method::SparseUpdate(search::default_policy(&meta, 0.0)),
        Method::tinytrain_default(),
    ];
    eprintln!(
        "[grid] {}: {} methods x {} domains x {} episodes on {} workers (analytic backend)",
        meta.arch,
        methods.len(),
        domains.len(),
        cfg.episodes,
        cfg.workers
    );
    let t0 = std::time::Instant::now();
    let stats = parallel::accuracy_grid(&meta, &params, &methods, &domains, &cfg)?;
    let mut cols: Vec<&str> = domains.iter().map(|s| s.as_str()).collect();
    cols.push("Avg.");
    let mut table = Table::new(
        &format!(
            "Parallel analytic grid — {} ({} episodes x {} steps, {} workers)",
            meta.arch,
            cfg.episodes,
            cfg.steps,
            cfg.workers
        ),
        &cols,
    );
    for (method, row) in methods.iter().zip(&stats) {
        let mut cells: Vec<String> = row.iter().map(|c| fmt_pct(c.mean_acc)).collect();
        let avg = row.iter().map(|c| c.mean_acc).sum::<f64>() / row.len().max(1) as f64;
        cells.push(fmt_pct(avg));
        table.row(&method.label(), cells);
    }
    println!("{}", table.to_markdown());
    eprintln!(
        "[grid] {} episodes in {:.2}s wall",
        methods.len() * domains.len() * cfg.episodes,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Metadata + weights for the artifact-light analytic commands:
/// deployed artifacts when present, the synthetic 8-block arch
/// otherwise — so `grid` and `serve` run in any checkout.
fn analytic_model(args: &Args, tag: &str) -> Result<(ModelMeta, ParamStore)> {
    let arch = args.str("arch", "mcunet");
    match ArtifactStore::discover(args.opt("artifacts")) {
        Ok(store) => {
            let arts = store.model(&arch);
            let meta = ModelMeta::load(&arts.meta)?;
            let params = ParamStore::load_or_init(&meta, &arts.weights, 42);
            Ok((meta, params))
        }
        Err(_) => {
            eprintln!("[{tag}] no artifacts found — using the synthetic 8-block arch");
            let meta = ModelMeta::synthetic(8);
            let params = ParamStore::init(&meta, 42);
            Ok((meta, params))
        }
    }
}

/// Parse `--faults SPEC` into a shared plan (None when absent).
fn fault_plan(args: &Args) -> Result<Option<Arc<serve::FaultPlan>>> {
    match args.opt("faults") {
        Some(spec) => Ok(Some(serve::FaultPlan::from_spec(&spec)?)),
        None => Ok(None),
    }
}

/// Parse the tenant-plane knobs shared by `serve` and `serve --listen`
/// (`--delta-budget-kb`, `--shards`, `--compact-depth`, `--quantize`)
/// into one [`serve::TenantStoreConfig`]. `--shards 0` (the default)
/// lets the builder auto-size from the worker count.
fn store_config(args: &Args) -> Result<serve::TenantStoreConfig> {
    let budget_bytes = match args.opt("delta-budget-kb") {
        Some(_) => args.f64("delta-budget-kb", f64::INFINITY) * 1e3,
        None => f64::INFINITY,
    };
    let quantize = match args.opt("quantize") {
        Some(spec) => serve::QuantPolicy::parse(&spec).map_err(|e| anyhow!("--quantize: {e}"))?,
        None => serve::QuantPolicy::Off,
    };
    Ok(serve::TenantStoreConfig {
        budget_bytes,
        shards: args.usize("shards", 0),
        compact_depth: args.usize("compact-depth", 4),
        quantize,
        spill_dir: None,
    })
}

/// Eviction-free, quantization-free, single-shard store for reference
/// arms and warm passes.
fn reference_store(base: Arc<ParamStore>) -> Result<serve::TenantStore> {
    serve::TenantStoreConfig { shards: 1, ..Default::default() }
        .build(base)
        .map_err(|e| anyhow!("reference store: {e}"))
}

/// Multi-tenant adaptation service replay: fan a synthetic
/// (tenants × domains × episodes) trace over the worker pool, report
/// throughput and latency percentiles, and check the results
/// bit-identical against the sequential-per-tenant reference arm.
fn serve(args: &Args) -> Result<()> {
    let (meta, params) = analytic_model(args, "serve")?;
    let trace_cfg = serve::TraceConfig {
        tenants: args.usize("tenants", 8),
        domains: args.list("domains", &["traffic", "cub"]),
        episodes: args.usize("episodes", 4),
        seed: args.u64("seed", 7),
        method: parse_method(&args.str("method", "tinytrain"), None, &meta)?,
        steps: args.usize("steps", 6),
        lr: args.f64("lr", 6e-3) as f32,
    };
    let faults = fault_plan(args)?;
    let cfg = serve::ServeConfig {
        workers: args.usize("workers", default_workers()),
        queue_capacity: args.usize("queue-cap", 64),
        render_cache: !args.bool("no-render-cache"),
        faults: faults.clone(),
        store: store_config(args)?,
        snapshot: None,
    };
    let mode = serve::LoopMode::parse(&args.str("mode", "open"))?;
    // Bit-identical replay needs eviction-free, quantization-free
    // stores; a finite budget or a quantize policy is for capacity
    // experiments, where the check is skipped.
    let budget = cfg.store.budget_bytes;
    let quantizing = cfg.store.quantize != serve::QuantPolicy::Off;
    let trace = serve::synthetic_trace(&trace_cfg);
    eprintln!(
        "[serve] {}: {} tenants x {} domains x {} episodes = {} requests, {} workers, {} loop",
        meta.arch,
        trace_cfg.tenants,
        trace_cfg.domains.len(),
        trace_cfg.episodes,
        trace.len(),
        cfg.workers,
        args.str("mode", "open"),
    );
    let base = Arc::new(params);

    // Untimed warm pass first: whichever timed arm ran first would
    // otherwise pay the shared render cache's cold misses for both,
    // biasing the reported scaling (the bench de-biases the same way).
    if cfg.render_cache {
        let warm = reference_store(Arc::clone(&base))?;
        serve::sequential_replay(&meta, &warm, &trace, true);
    }

    let seq_store = reference_store(Arc::clone(&base))?;
    let seq = serve::sequential_replay(&meta, &seq_store, &trace, cfg.render_cache);
    let store = cfg.build_store(Arc::clone(&base))?;
    let par = serve::replay(&meta, &store, &cfg, &trace, mode)?;

    if let Some(plan) = &faults {
        let c = plan.counts();
        eprintln!(
            "[serve] faults: {} panics, {} slows injected | {} submits recognised as retries",
            c.panics, c.slows, par.retried
        );
    }

    if !budget.is_infinite() {
        eprintln!(
            "[serve] finite delta budget ({}): skipping the bit-identity check \
             (LRU eviction timing depends on cross-tenant interleaving)",
            fmt_kb(budget)
        );
    } else if quantizing {
        eprintln!(
            "[serve] --quantize: skipping the bit-identity check \
             (int8 demotion rounds cold overlays by up to scale/2)"
        );
    } else if faults.is_some() && mode == serve::LoopMode::Open {
        eprintln!(
            "[serve] open loop with faults: skipping the bit-identity check \
             (failed episodes are only retried by the closed-loop driver)"
        );
    } else {
        serve::check_equivalent(&seq.completions, &par.completions)?;
        for t in 0..trace_cfg.tenants {
            let name = serve::tenant_name(t);
            if seq_store.delta(&name) != store.delta(&name) {
                return Err(anyhow!("tenant {name}: final delta diverged from reference"));
            }
        }
        eprintln!(
            "[serve] reference check: bit-identical to the sequential arm{}",
            if faults.is_some() { " — through the injected faults" } else { "" }
        );
    }

    let mut table = Table::new(
        &format!(
            "Adaptation service — {} ({} requests, {} loop)",
            meta.arch,
            trace.len(),
            args.str("mode", "open")
        ),
        &["wall s", "req/s", "p50", "p95", "p99", "errors"],
    );
    let arms = [("sequential x1".to_string(), &seq), (format!("service x{}", par.workers), &par)];
    for (label, r) in &arms {
        table.row(
            label,
            vec![
                format!("{:.3}", r.wall_s),
                format!("{:.1}", r.throughput_rps),
                fmt_us(r.total.p50_us),
                fmt_us(r.total.p95_us),
                fmt_us(r.total.p99_us),
                format!("{}", r.errors),
            ],
        );
    }
    println!("{}", table.to_markdown());
    let stats = store.stats();
    eprintln!(
        "[serve] throughput {:.2}x over sequential | store: {} tenants ({} quantized) on \
         {} shards, {} in deltas, {} absorbs, {} evictions, {} quantizations, \
         {} compactions, {} contended",
        par.throughput_rps / seq.throughput_rps.max(1e-12),
        stats.tenants,
        stats.quantized,
        stats.shards,
        fmt_kb(stats.delta_bytes),
        stats.absorbs,
        stats.evictions,
        stats.quantizations,
        stats.compactions,
        stats.contended
    );
    Ok(())
}

/// `serve --listen`: expose the adaptation service over HTTP and block
/// until a `POST /v1/shutdown` arrives. Prints the bound address on
/// stdout (port 0 binds an ephemeral port; scripts scrape this line).
fn serve_listen(args: &Args, addr: &str) -> Result<()> {
    use std::io::Write as _;
    let (meta, params) = analytic_model(args, "serve")?;
    let state_dir = args.opt("state-dir").map(std::path::PathBuf::from);
    let mut store_cfg = store_config(args)?;
    // With a state dir, evicted tenants spill to disk and page back in
    // on demand instead of silently losing their adaptation.
    store_cfg.spill_dir = state_dir.as_ref().map(|dir| dir.join("spill"));
    let cfg = net::ServerConfig {
        acceptors: args.usize("acceptors", 4),
        limits: net::Limits::default(),
        verify_decode: args.bool("verify-decode"),
        serve: serve::ServeConfig {
            workers: args.usize("workers", default_workers()),
            queue_capacity: args.usize("queue-cap", 64),
            render_cache: !args.bool("no-render-cache"),
            faults: fault_plan(args)?,
            store: store_cfg,
            snapshot: state_dir.as_ref().map(|dir| serve::SnapshotConfig {
                path: dir.join("tenants.snap"),
                every: std::time::Duration::from_secs(args.u64("snapshot-every-s", 5)),
            }),
        },
    };
    let store = cfg.serve.build_store(Arc::new(params))?;
    if let Some(dir) = &state_dir {
        let snap_path = dir.join("tenants.snap");
        match serve::snapshot::load_or_quarantine(&snap_path) {
            serve::Restore::Absent => {}
            serve::Restore::Loaded(entries) => {
                eprintln!(
                    "[serve] restored {} tenants from {}",
                    entries.len(),
                    snap_path.display()
                );
                store.restore_entries(entries);
            }
            serve::Restore::Quarantined { to, reason } => {
                eprintln!(
                    "[serve] snapshot corrupt ({reason}); quarantined to {} — fresh boot",
                    to.display()
                );
            }
        }
    }
    let listener = std::net::TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    // The loadgen/CI handshake line — keep the format stable.
    println!("listening on http://{local}");
    std::io::stdout().flush().ok();
    eprintln!(
        "[serve] {}: http on {local} ({} handlers, {} workers{})",
        meta.arch,
        cfg.acceptors,
        cfg.serve.workers,
        if cfg.verify_decode { ", verify-decode" } else { "" }
    );
    net::serve_blocking(listener, &meta, &store, &cfg)?;
    let stats = store.stats();
    // The chaos-smoke scripts grep this line — keep the field names.
    eprintln!(
        "[serve] shutdown complete | store: {} tenants ({} quantized) on {} shards, \
         {} in deltas, {} quantizations, {} promotions, {} compactions, {} contended",
        stats.tenants,
        stats.quantized,
        stats.shards,
        fmt_kb(stats.delta_bytes),
        stats.quantizations,
        stats.promotions,
        stats.compactions,
        stats.contended
    );
    Ok(())
}

/// Socket-driven load generator: replay a synthetic trace against a
/// `serve --listen` server, then (unless `--no-verify`) run the same
/// trace through the in-process sequential arm and assert the wire
/// completions and final tenant deltas are bit-identical.
fn loadgen(args: &Args) -> Result<()> {
    let addr = args
        .opt("addr")
        .ok_or_else(|| anyhow!("usage: tinytrain loadgen --addr HOST:PORT [--connections N]"))?;
    let (meta, params) = analytic_model(args, "loadgen")?;
    let method_name = args.str("method", "tinytrain");
    let trace_cfg = serve::TraceConfig {
        tenants: args.usize("tenants", 8),
        domains: args.list("domains", &["traffic", "cub"]),
        episodes: args.usize("episodes", 4),
        seed: args.u64("seed", 7),
        method: parse_method(&method_name, None, &meta)?,
        steps: args.usize("steps", 6),
        lr: args.f64("lr", 6e-3) as f32,
    };
    let mode = serve::LoopMode::parse(&args.str("mode", "closed"))?;
    let cfg = net::WireConfig {
        connections: args.usize("connections", 4),
        mode,
        method: method_name,
        limits: net::Limits::client(),
        shutdown: args.bool("shutdown"),
        faults: fault_plan(args)?,
        deadline_ms: args.opt("deadline-ms").map(|_| args.u64("deadline-ms", 0)),
        retry_attempts: args.usize("retry-attempts", 8) as u32,
        retry_seed: args.u64("retry-seed", 7),
    };
    // The full trace is episode-major, so slicing whole episode blocks
    // (`--from-ep`/`--to-ep`) keeps every tenant's requests in order —
    // the split-run shape the restart smoke drives.
    let full_trace = serve::synthetic_trace(&trace_cfg);
    let block = trace_cfg.tenants * trace_cfg.domains.len();
    let episodes = trace_cfg.episodes;
    let from_ep = args.usize("from-ep", 0).min(episodes);
    let to_ep = args.usize("to-ep", episodes).min(episodes);
    if from_ep >= to_ep {
        return Err(anyhow!("empty episode slice: --from-ep {from_ep} --to-ep {to_ep}"));
    }
    let trace = &full_trace[from_ep * block..to_ep * block];
    eprintln!(
        "[loadgen] {}: {} requests (episodes {from_ep}..{to_ep}) -> {} ({} loop, \
         {} connections requested)",
        meta.arch,
        trace.len(),
        addr,
        args.str("mode", "closed"),
        cfg.connections
    );
    let report = net::run_wire(&addr, &meta, trace, &cfg)?;
    let errors = report.completions.iter().filter(|c| c.result.is_err()).count();
    let r = &report.retries;
    if r != &net::RetryCounts::default() {
        eprintln!(
            "[loadgen] recoveries: {} transport retries, {} sheds retried, \
             {} failed episodes resubmitted, {} injected connection drops",
            r.transport, r.shed, r.failed, r.dropped_connections
        );
    }
    let base = Arc::new(params);
    if args.bool("no-verify") {
        eprintln!("[loadgen] --no-verify: skipping the reference arm");
    } else if args.bool("verify-full-trace") {
        // Split-run verification: completions from earlier phases died
        // with the previous server process, but the surviving tenant
        // state must still equal one uninterrupted sequential pass.
        // Against a `--quantize` server, `--quant-slack S` loosens the
        // comparison to S half-steps of each run's int8 grid.
        match args.opt("quant-slack") {
            Some(_) => {
                let slack = args.f64("quant-slack", 2.0);
                net::verify_final_deltas_within_quant_error(
                    &meta,
                    base,
                    &full_trace,
                    &report.syncs,
                    !args.bool("no-render-cache"),
                    slack,
                )?;
                eprintln!(
                    "[loadgen] full-trace check: final deltas of {} tenants within {}x the \
                     int8 quantization error of one uninterrupted sequential pass over all \
                     {} episodes",
                    report.syncs.len(),
                    slack,
                    episodes
                );
            }
            None => {
                net::verify_final_deltas(
                    &meta,
                    base,
                    &full_trace,
                    &report.syncs,
                    !args.bool("no-render-cache"),
                )?;
                eprintln!(
                    "[loadgen] full-trace check: final deltas of {} tenants bit-identical to \
                     one uninterrupted sequential pass over all {} episodes",
                    report.syncs.len(),
                    episodes
                );
            }
        }
    } else {
        net::verify_against_reference(
            &meta,
            base,
            trace,
            &report,
            !args.bool("no-render-cache"),
        )?;
        eprintln!(
            "[loadgen] reference check: wire results bit-identical to the in-process arm \
             ({} completions, {} tenants synced)",
            report.completions.len(),
            report.syncs.len()
        );
    }
    let mut table = Table::new(
        &format!(
            "Wire replay — {} requests over {} connections ({} loop)",
            trace.len(),
            report.connections,
            args.str("mode", "closed")
        ),
        &["wall s", "req/s", "p50", "p95", "p99", "errors"],
    );
    table.row(
        "wire",
        vec![
            format!("{:.3}", report.wall_s),
            format!("{:.1}", report.throughput_rps),
            fmt_us(report.total.p50_us),
            fmt_us(report.total.p95_us),
            fmt_us(report.total.p99_us),
            format!("{errors}"),
        ],
    );
    println!("{}", table.to_markdown());
    Ok(())
}

fn announce_episode(arch: &str, domain_name: &str, ep: &Episode) {
    eprintln!(
        "adapting {} to {}: {} ways, {} support, {} query",
        arch,
        domain_name,
        ep.ways,
        ep.support.len(),
        ep.query.len()
    );
}

fn report_episode(res: tinytrain::coordinator::EpisodeResult) -> Result<()> {
    println!(
        "method={} backend={} acc {:.1}% -> {:.1}% | selection {:.2}s train {:.2}s | layers {:?}",
        res.method,
        res.backend,
        res.acc_before * 100.0,
        res.acc_after * 100.0,
        res.selection_s,
        res.train_s,
        res.selected_layers
    );
    Ok(())
}

fn parse_backend(name: &str) -> Result<Backend> {
    Ok(match name {
        "auto" => Backend::Auto,
        "host" => Backend::Host,
        "device" => Backend::Device,
        "analytic" => Backend::Analytic,
        other => return Err(anyhow!("unknown backend '{other}'")),
    })
}

/// `store` feeds the SparseUpdate policy lookup; without one (the
/// artifact-free `serve` path) the derived default policy is used.
fn parse_method(name: &str, store: Option<&ArtifactStore>, meta: &ModelMeta) -> Result<Method> {
    Ok(match name {
        "none" => Method::None,
        "fulltrain" => Method::FullTrain,
        "lastlayer" => Method::LastLayer,
        "tinytl" => Method::TinyTl,
        "sparseupdate" => {
            let policy = store
                .and_then(|s| {
                    let path = s.dir.join(format!("sparse_policy_{}.json", meta.arch));
                    search::load_policy(&path).ok()
                })
                .unwrap_or_else(|| search::default_policy(meta, 0.0));
            Method::SparseUpdate(policy)
        }
        "tinytrain" => Method::tinytrain_default(),
        other => return Err(anyhow!("unknown method '{other}'")),
    })
}

/// Print artifact + architecture summary.
fn info(args: &Args) -> Result<()> {
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover(args.opt("artifacts"))?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {}", store.dir.display());
    for arch in args.list("arch", &harness::ALL_ARCHS) {
        let engine = ModelEngine::load(&rt, &store, &arch)?;
        let s = &engine.meta.scaled;
        let p = &engine.meta.paper;
        println!(
            "{arch}: scaled {} layers / {} blocks, {:.1}k params, {:.2}M MACs @{}px | \
             paper {:.2}M params, {:.1}M MACs @{}px | theta={} fisher={}",
            s.layers.len(),
            s.blocks.len(),
            s.total_params as f64 / 1e3,
            s.total_macs as f64 / 1e6,
            s.img,
            p.total_params as f64 / 1e6,
            p.total_macs as f64 / 1e6,
            p.img,
            engine.meta.total_theta,
            engine.meta.fisher_len,
        );
    }
    Ok(())
}
