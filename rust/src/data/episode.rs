//! Various-way-various-shot episode sampler (paper Appendix B,
//! following Triantafillou et al. 2020), scaled to this testbed's
//! static-shape maxima.
//!
//! Sampling procedure per episode:
//!   1. ways ~ U[3, min(MAX_WAYS, n_classes)], classes chosen uniformly.
//!   2. support: imbalanced shots — each class draws an unnormalised
//!      log-uniform mass, masses are scaled to the support budget, every
//!      class keeps >= 1 shot (realistically imbalanced, Table 5).
//!   3. query: class-balanced, min(10, MAX_QUERY / ways) per class
//!      (paper: 10 per class).
//!
//! Hot-path notes (README "Hot-path design"): images come out of the
//! shared [`RenderCache`] as `Arc<[f32]>` (one pointer clone per reuse,
//! stream-exact determinism), and every padded/pseudo tensor is a pooled
//! [`PoolBuf`] from the thread-local scratch arena — the steady-state
//! episode loop allocates no tensor-sized buffers.

use std::sync::Arc;

use super::cache::RenderCache;
use super::domains::Domain;
use crate::model::EpisodeShapes;
use crate::util::pool::{take_zeroed, PoolBuf};
use crate::util::rng::Rng;

/// One sampled image with its episode-local label. The image is shared
/// with the render cache (and any other episode that drew the same
/// render), so cloning a `Sample` never copies pixels.
#[derive(Debug, Clone)]
pub struct Sample {
    pub image: Arc<[f32]>, // IMG*IMG*3, NHWC [-1,1]
    pub label: usize,      // way index in [0, ways)
}

/// A fully materialised episode (unpadded).
#[derive(Debug, Clone)]
pub struct Episode {
    pub domain: String,
    pub ways: usize,
    pub class_ids: Vec<usize>,
    pub shots: Vec<usize>, // support shots per way
    pub support: Vec<Sample>,
    pub query: Vec<Sample>,
}

/// Pseudo-query tensors for on-device fine-tuning (Hu et al., 2022):
/// augmented copies of the support images, padded to the static
/// `max_query` shape. Replaces the `(x, y, v)` tuple that used to be
/// threaded through the engine and trainer.
#[derive(Debug, Clone)]
pub struct PseudoQuery {
    /// Images, `(max_query, img, img, channels)` row-major.
    pub x: PoolBuf,
    /// One-hot labels, `(max_query, max_ways)`.
    pub y: PoolBuf,
    /// Validity mask, `(max_query,)` — 0 on padded rows.
    pub v: PoolBuf,
}

impl PseudoQuery {
    /// Check the flat buffers against the episode shape constants. The
    /// AOT graphs have static shapes, so a mismatch here means a crash
    /// (or silent garbage) inside PJRT — fail early instead.
    pub fn validate(&self, s: &EpisodeShapes) -> Result<(), String> {
        let img_len = s.img * s.img * s.channels;
        if self.x.len() != s.max_query * img_len {
            return Err(format!(
                "pseudo-query x has {} floats, expected {} ({}x{}x{}x{})",
                self.x.len(),
                s.max_query * img_len,
                s.max_query,
                s.img,
                s.img,
                s.channels
            ));
        }
        if self.y.len() != s.max_query * s.max_ways {
            return Err(format!(
                "pseudo-query y has {} floats, expected {}",
                self.y.len(),
                s.max_query * s.max_ways
            ));
        }
        if self.v.len() != s.max_query {
            return Err(format!(
                "pseudo-query v has {} floats, expected {}",
                self.v.len(),
                s.max_query
            ));
        }
        Ok(())
    }
}

/// Episode padded to the AOT graphs' static shapes. Tensor fields are
/// pooled buffers (deref to `[f32]`) so padding an episode is
/// allocation-free once the thread's arena is warm.
#[derive(Debug, Clone)]
pub struct PaddedEpisode {
    pub sup_x: PoolBuf,
    pub sup_y: PoolBuf,
    pub sup_v: PoolBuf,
    pub qry_x: PoolBuf,
    pub qry_y: PoolBuf,
    pub qry_v: PoolBuf,
    pub n_support: usize,
    pub n_query: usize,
    pub ways: usize,
}

pub struct Sampler<'a> {
    pub domain: &'a dyn Domain,
    pub shapes: &'a EpisodeShapes,
    pub min_ways: usize,
    /// Render cache consulted per sample; `None` rasterizes every image.
    cache: Option<&'a RenderCache>,
}

impl<'a> Sampler<'a> {
    /// A sampler over the process-wide [`RenderCache::global`].
    pub fn new(domain: &'a dyn Domain, shapes: &'a EpisodeShapes) -> Self {
        Sampler { domain, shapes, min_ways: 3, cache: Some(RenderCache::global()) }
    }

    /// Override the render cache (`None` disables caching — every image
    /// is rasterized). Output is bit-identical either way; this knob
    /// exists for benchmarks and the determinism tests.
    pub fn with_cache(mut self, cache: Option<&'a RenderCache>) -> Self {
        self.cache = cache;
        self
    }

    fn render(&self, class: usize, rng: &mut Rng) -> Arc<[f32]> {
        match self.cache {
            Some(c) => c.render(self.domain, class, rng, self.shapes.img),
            None => self.domain.render(class, rng, self.shapes.img).into(),
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> Episode {
        let s = self.shapes;
        let max_ways = s.max_ways.min(self.domain.n_classes());
        let ways = rng.int_range(self.min_ways.min(max_ways), max_ways);
        let class_ids = rng.choose_k(self.domain.n_classes(), ways);

        // Imbalanced support shots: log-uniform masses scaled to budget.
        let budget = s.max_support;
        let masses: Vec<f64> = (0..ways).map(|_| (rng.range(0.0, 2.2)).exp()).collect();
        let total: f64 = masses.iter().sum();
        let mut shots: Vec<usize> = masses
            .iter()
            .map(|m| ((m / total * budget as f64).floor() as usize).max(1))
            .collect();
        // Trim any overshoot from the largest classes.
        while shots.iter().sum::<usize>() > budget {
            let i = shots
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(i, _)| i)
                .unwrap();
            shots[i] -= 1;
        }

        let q_per_class = (s.max_query / ways).min(10).max(1);

        let mut support = Vec::new();
        let mut query = Vec::new();
        for (w, &cls) in class_ids.iter().enumerate() {
            for _ in 0..shots[w] {
                support.push(Sample { image: self.render(cls, rng), label: w });
            }
            for _ in 0..q_per_class {
                query.push(Sample { image: self.render(cls, rng), label: w });
            }
        }
        rng.shuffle(&mut support);
        rng.shuffle(&mut query);
        Episode {
            domain: self.domain.name().to_string(),
            ways,
            class_ids,
            shots,
            support,
            query,
        }
    }
}

impl Episode {
    /// Pad to the static AOT shapes, producing the graph input tensors.
    pub fn pad(&self, s: &EpisodeShapes) -> PaddedEpisode {
        let img_len = s.img * s.img * s.channels;
        let pack = |samples: &[Sample], cap: usize| {
            let mut x = take_zeroed(cap * img_len);
            let mut y = take_zeroed(cap * s.max_ways);
            let mut v = take_zeroed(cap);
            for (i, smp) in samples.iter().take(cap).enumerate() {
                x[i * img_len..(i + 1) * img_len].copy_from_slice(&smp.image);
                y[i * s.max_ways + smp.label] = 1.0;
                v[i] = 1.0;
            }
            (x, y, v)
        };
        let (sup_x, sup_y, sup_v) = pack(&self.support, s.max_support);
        let (qry_x, qry_y, qry_v) = pack(&self.query, s.max_query);
        PaddedEpisode {
            sup_x,
            sup_y,
            sup_v,
            qry_x,
            qry_y,
            qry_v,
            n_support: self.support.len().min(s.max_support),
            n_query: self.query.len().min(s.max_query),
            ways: self.ways,
        }
    }

    /// Pseudo-query set for fine-tuning (Hu et al., 2022): augmented
    /// copies of the *support* images — the only labelled data available
    /// on-device. Augmentations: horizontal flip, +-2px shift, noise,
    /// written straight into the pooled destination rows (no per-image
    /// staging buffer).
    pub fn pseudo_query(&self, s: &EpisodeShapes, rng: &mut Rng) -> PseudoQuery {
        let img_len = s.img * s.img * s.channels;
        let cap = s.max_query;
        let mut x = take_zeroed(cap * img_len);
        let mut y = take_zeroed(cap * s.max_ways);
        let mut v = take_zeroed(cap);
        if self.support.is_empty() {
            return PseudoQuery { x, y, v };
        }
        // Every pseudo row is filled: support images are sampled with
        // replacement, so a short support set still yields `cap` rows.
        for i in 0..cap {
            let src = &self.support[rng.below(self.support.len())];
            let row = &mut x[i * img_len..(i + 1) * img_len];
            augment_into(&src.image, s.img, s.channels, rng, row);
            y[i * s.max_ways + src.label] = 1.0;
            v[i] = 1.0;
        }
        PseudoQuery { x, y, v }
    }
}

/// Light augmentation on a flat NHWC image, written into `out`
/// (`out.len() == img.len()`; every element is overwritten).
pub fn augment_into(img: &[f32], size: usize, channels: usize, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(img.len(), out.len());
    let flip = rng.bool(0.5);
    let dx = rng.int_range(0, 4) as i32 - 2;
    let dy = rng.int_range(0, 4) as i32 - 2;
    let noise_amp = 0.05f32;
    for y in 0..size {
        for x in 0..size {
            let sx0 = if flip { size as i32 - 1 - x as i32 } else { x as i32 } + dx;
            let sy0 = y as i32 + dy;
            let sx = sx0.clamp(0, size as i32 - 1) as usize;
            let sy = sy0.clamp(0, size as i32 - 1) as usize;
            for ch in 0..channels {
                let v = img[(sy * size + sx) * channels + ch]
                    + (rng.uniform() as f32 - 0.5) * 2.0 * noise_amp;
                out[(y * size + x) * channels + ch] = v.clamp(-1.0, 1.0);
            }
        }
    }
}

/// Allocating wrapper around [`augment_into`].
pub fn augment(img: &[f32], size: usize, channels: usize, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0.0f32; img.len()];
    augment_into(img, size, channels, rng, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::domains::Traffic;
    use crate::util::prop::check;

    fn shapes() -> EpisodeShapes {
        EpisodeShapes {
            img: 16,
            channels: 3,
            max_ways: 6,
            max_support: 20,
            max_query: 18,
            eval_batch: 38,
            feat_dim: 8,
            cosine_tau: 10.0,
        }
    }

    #[test]
    fn episode_respects_budgets_property() {
        let s = shapes();
        check(
            "episode-budgets",
            40,
            1,
            |r| {
                let d = Traffic;
                Sampler::new(&d, &s).sample(r)
            },
            |ep| {
                if ep.ways < 3 || ep.ways > s.max_ways {
                    return Err(format!("ways {} out of range", ep.ways));
                }
                if ep.support.len() > s.max_support {
                    return Err(format!("support {} over budget", ep.support.len()));
                }
                if ep.shots.iter().any(|&k| k == 0) {
                    return Err("class with zero shots".into());
                }
                if ep.shots.len() != ep.ways || ep.class_ids.len() != ep.ways {
                    return Err("ways/shots mismatch".into());
                }
                // every way has at least one query sample
                for w in 0..ep.ways {
                    if !ep.query.iter().any(|q| q.label == w) {
                        return Err(format!("way {w} has no query"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn padding_is_consistent() {
        let s = shapes();
        let d = Traffic;
        let mut rng = Rng::new(3);
        let ep = Sampler::new(&d, &s).sample(&mut rng);
        let p = ep.pad(&s);
        assert_eq!(p.sup_x.len(), s.max_support * s.img * s.img * 3);
        assert_eq!(p.sup_y.len(), s.max_support * s.max_ways);
        let n_valid = p.sup_v.iter().filter(|&&v| v == 1.0).count();
        assert_eq!(n_valid, ep.support.len());
        // one-hot rows sum to 1 on valid entries, 0 on padded ones
        for i in 0..s.max_support {
            let row_sum: f32 = p.sup_y[i * s.max_ways..(i + 1) * s.max_ways].iter().sum();
            assert_eq!(row_sum, p.sup_v[i]);
        }
    }

    #[test]
    fn cached_and_uncached_sampling_agree() {
        let s = shapes();
        let d = Traffic;
        for seed in [3u64, 8, 21] {
            let mut r_off = Rng::new(seed);
            let off = Sampler::new(&d, &s).with_cache(None).sample(&mut r_off);
            let cache = RenderCache::new(2, 256);
            let mut r_on = Rng::new(seed);
            let on = Sampler::new(&d, &s).with_cache(Some(&cache)).sample(&mut r_on);
            // replay the same stream again: all renders hit
            let mut r_hit = Rng::new(seed);
            let hit = Sampler::new(&d, &s).with_cache(Some(&cache)).sample(&mut r_hit);
            assert!(cache.stats().hits > 0);
            for (a, b) in [(&off, &on), (&off, &hit)] {
                assert_eq!(a.ways, b.ways);
                assert_eq!(a.class_ids, b.class_ids);
                assert_eq!(a.support.len(), b.support.len());
                for (x, y) in a.support.iter().zip(&b.support) {
                    assert_eq!(x.label, y.label);
                    assert_eq!(&x.image[..], &y.image[..]);
                }
            }
            assert_eq!(r_off.state(), r_on.state(), "cache must not shift the stream");
            assert_eq!(r_off.state(), r_hit.state(), "hits must not shift the stream");
        }
    }

    #[test]
    fn pseudo_query_labels_come_from_support() {
        let s = shapes();
        let d = Traffic;
        let mut rng = Rng::new(5);
        let ep = Sampler::new(&d, &s).sample(&mut rng);
        let pq = ep.pseudo_query(&s, &mut rng);
        pq.validate(&s).unwrap();
        for i in 0..s.max_query {
            let row = &pq.y[i * s.max_ways..(i + 1) * s.max_ways];
            let row_sum: f32 = row.iter().sum();
            assert_eq!(row_sum, pq.v[i]);
            // labels only within sampled ways
            for (w, &val) in row.iter().enumerate() {
                if val > 0.0 {
                    assert!(w < ep.ways);
                }
            }
        }
    }

    #[test]
    fn pseudo_query_validate_catches_shape_drift() {
        let s = shapes();
        let d = Traffic;
        let mut rng = Rng::new(6);
        let ep = Sampler::new(&d, &s).sample(&mut rng);
        let mut pq = ep.pseudo_query(&s, &mut rng);
        assert!(pq.validate(&s).is_ok());
        let mut short = pq.x.to_vec();
        short.pop();
        pq.x = short.into();
        assert!(pq.validate(&s).unwrap_err().contains("pseudo-query x"));
        let mut pq = ep.pseudo_query(&s, &mut rng);
        let mut long = pq.y.to_vec();
        long.push(0.0);
        pq.y = long.into();
        assert!(pq.validate(&s).unwrap_err().contains("pseudo-query y"));
        let mut pq = ep.pseudo_query(&s, &mut rng);
        pq.v = Vec::new().into();
        assert!(pq.validate(&s).unwrap_err().contains("pseudo-query v"));
    }

    #[test]
    fn augment_preserves_range_and_shape() {
        let mut rng = Rng::new(9);
        let img: Vec<f32> = (0..16 * 16 * 3).map(|i| ((i % 13) as f32 / 6.5) - 1.0).collect();
        let out = augment(&img, 16, 3, &mut rng);
        assert_eq!(out.len(), img.len());
        assert!(out.iter().all(|v| (-1.0..=1.0).contains(v)));
        // in-place form consumes the identical rng stream
        let mut rng2 = Rng::new(9);
        let mut out2 = vec![9.0f32; img.len()];
        augment_into(&img, 16, 3, &mut rng2, &mut out2);
        assert_eq!(out, out2);
        assert_eq!(rng.state(), rng2.state());
    }
}
