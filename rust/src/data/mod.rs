//! Data substrate: procedural cross-domain datasets + episodic sampling.
//!
//! Replaces the paper's MiniImageNet / Meta-Dataset pipeline with
//! generators whose cross-domain statistics exercise the same CDFSL
//! behaviour (DESIGN.md "Substitutions").

pub mod cache;
pub mod domains;
pub mod episode;
pub mod raster;
pub mod stats;

pub use cache::{RenderCache, RenderCacheStats};
pub use domains::{all_domains, domain_by_name, Domain, DOMAIN_NAMES};
pub use episode::{augment, augment_into, Episode, PaddedEpisode, PseudoQuery, Sampler, Sample};
pub use stats::{domain_stats, mean_sd, DomainStats};
