//! Source (meta-train) domain — the MiniImageNet stand-in.
//!
//! 64 classes, each drawing its generator *family* and parameters from a
//! private seed stream disjoint from every meta-test domain. The class
//! distribution intentionally spans shapes, strokes and textures so the
//! meta-learned representation is generic, while remaining *out of
//! domain* w.r.t. all nine targets (different seeds => different class
//! parameter vectors; cross-domain shift preserved).

use super::Domain;
use crate::data::raster::{hsv, rand_color, Canvas};
use crate::util::rng::Rng;

pub struct SourceMix;

impl Domain for SourceMix {
    fn name(&self) -> &'static str {
        "source"
    }

    fn seed(&self) -> u64 {
        0x50EC
    }

    fn n_classes(&self) -> usize {
        64 // MiniImageNet's meta-train class count
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        let family = crng.below(6);
        let col_a = hsv(crng.range(0.0, 6.0) as f32, 0.7, 0.8);
        let col_b = hsv(crng.range(0.0, 6.0) as f32, 0.5, 0.55);
        let p1 = crng.range(0.15, 0.4) as f32;
        let p2 = crng.range(0.3, 0.9) as f32;
        let n = crng.int_range(3, 9);

        let s = img as f32;
        let mut c = Canvas::new(img, img, rand_muted(rng));
        c.noise(rng, 4, 0.15);
        let cx = s * 0.5 + rng.range(-0.1, 0.1) as f32 * s;
        let cy = s * 0.5 + rng.range(-0.1, 0.1) as f32 * s;
        let r = p1 * s * (0.85 + rng.range(0.0, 0.3) as f32);
        let rot = rng.range(0.0, std::f64::consts::TAU) as f32;

        match family {
            0 => {
                // concentric n-gons
                c.ngon(cx, cy, r * 1.3, n, rot, col_a);
                c.ngon(cx, cy, r * 0.8, n, rot + 0.3, col_b);
            }
            1 => {
                // ring cluster
                for i in 0..n {
                    let a = rot + std::f32::consts::TAU * i as f32 / n as f32;
                    c.disk(cx + r * a.cos(), cy + r * a.sin(), r * 0.4, col_a);
                }
                c.disk(cx, cy, r * 0.5, col_b);
            }
            2 => {
                // strokes
                for i in 0..n {
                    let a = rot + i as f32 * p2;
                    c.line(
                        cx - r * a.cos(),
                        cy - r * a.sin(),
                        cx + r * a.cos(),
                        cy + r * a.sin(),
                        1.5,
                        if i % 2 == 0 { col_a } else { col_b },
                    );
                }
            }
            3 => {
                // texture patch
                c.grating(p2, rot, 0.0, 0.7, col_a);
                c.ngon(cx, cy, r, 4, rot, col_b);
            }
            4 => {
                // blob + satellite
                c.ellipse(cx, cy, r * 1.2, r * 0.7, rot, col_a);
                c.disk(cx + r, cy - r * 0.6, r * 0.35, col_b);
                c.disk(cx - r, cy + r * 0.6, r * 0.25, col_b);
            }
            _ => {
                // nested rings
                c.ring(cx, cy, r * 1.2, r * 0.25, col_a);
                c.ring(cx, cy, r * 0.7, r * 0.2, col_b);
                c.disk(cx, cy, r * 0.25, rand_color(rng));
            }
        }
        c.to_vec()
    }
}

fn rand_muted(rng: &mut Rng) -> [f32; 3] {
    let c = rand_color(rng);
    [c[0] * 0.4 + 0.25, c[1] * 0.4 + 0.25, c[2] * 0.4 + 0.25]
}
