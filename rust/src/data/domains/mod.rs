//! Synthetic cross-domain dataset suite.
//!
//! Stands in for the paper's Meta-Dataset targets (DESIGN.md
//! "Substitutions"): nine procedurally generated domains with genuinely
//! different low-level statistics (shape-, stroke-, texture- and
//! clutter-dominated) plus a 64-class mixed `source` domain used for
//! offline meta-training. Classes are seeded parameter vectors; samples
//! are jittered renders, so every episode is reproducible from its seed
//! and *meta-test classes are never seen at meta-train time* (different
//! generator seeds and families per split).

mod aircraft;
mod cub;
mod coco;
mod dtd;
mod flower;
mod fungi;
mod omniglot;
mod qdraw;
mod source;
mod traffic;

pub use aircraft::Aircraft;
pub use coco::Coco;
pub use cub::Cub;
pub use dtd::Dtd;
pub use flower::Flower;
pub use fungi::Fungi;
pub use omniglot::Omniglot;
pub use qdraw::QDraw;
pub use source::SourceMix;
pub use traffic::Traffic;

use crate::util::rng::Rng;

/// A procedural image domain. `render` draws one sample of `class` at
/// `img`x`img` resolution into an NHWC [-1,1] vector; all class-level
/// randomness must derive from `class_rng(class)` so that the class
/// identity is stable across samples, while per-sample jitter comes from
/// the caller's `rng`.
///
/// Purity contract: `render` must be a pure function of `(class, rng
/// position, img)` — no interior state, no randomness outside the
/// passed stream. The shared [`RenderCache`](crate::data::RenderCache)
/// relies on this to replay cached tensors with stream-exact RNG
/// restoration; an impure implementation would silently break the
/// grid's bit-determinism when cached.
pub trait Domain: Send + Sync {
    fn name(&self) -> &'static str;
    /// Number of classes in the meta-test split.
    fn n_classes(&self) -> usize;
    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32>;

    /// Deterministic per-class parameter stream.
    fn class_rng(&self, class: usize) -> Rng {
        let mut h = Rng::new(self.seed() ^ (class as u64).wrapping_mul(0x9e3779b97f4a7c15));
        h.next_u64();
        h
    }

    fn seed(&self) -> u64;
}

/// The nine meta-test domains in the paper's column order (Table 1).
pub fn all_domains() -> Vec<Box<dyn Domain>> {
    vec![
        Box::new(Traffic),
        Box::new(Omniglot),
        Box::new(Aircraft),
        Box::new(Flower),
        Box::new(Cub),
        Box::new(Dtd),
        Box::new(QDraw),
        Box::new(Fungi),
        Box::new(Coco),
    ]
}

pub fn domain_by_name(name: &str) -> Option<Box<dyn Domain>> {
    let d: Box<dyn Domain> = match name {
        "traffic" => Box::new(Traffic),
        "omniglot" => Box::new(Omniglot),
        "aircraft" => Box::new(Aircraft),
        "flower" => Box::new(Flower),
        "cub" => Box::new(Cub),
        "dtd" => Box::new(Dtd),
        "qdraw" => Box::new(QDraw),
        "fungi" => Box::new(Fungi),
        "coco" => Box::new(Coco),
        "source" => Box::new(SourceMix),
        _ => return None,
    };
    Some(d)
}

pub const DOMAIN_NAMES: [&str; 9] = [
    "traffic", "omniglot", "aircraft", "flower", "cub", "dtd", "qdraw", "fungi", "coco",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_domains_render_valid_images() {
        for d in all_domains() {
            let mut rng = Rng::new(1);
            let img = d.render(0, &mut rng, 32);
            assert_eq!(img.len(), 32 * 32 * 3, "{}", d.name());
            assert!(
                img.iter().all(|v| (-1.0..=1.0).contains(v)),
                "{} out of range",
                d.name()
            );
            assert!(d.n_classes() >= 20, "{} too few classes", d.name());
        }
    }

    #[test]
    fn classes_are_distinguishable_samples_vary() {
        for d in all_domains() {
            let mut r1 = Rng::new(10);
            let mut r2 = Rng::new(11);
            let a = d.render(0, &mut r1, 32);
            let b = d.render(0, &mut r2, 32);
            let c = d.render(1, &mut Rng::new(10), 32);
            // samples of same class differ (jitter), classes differ more
            assert_ne!(a, b, "{}: no sample jitter", d.name());
            assert_ne!(a, c, "{}: classes identical", d.name());
        }
    }

    #[test]
    fn rendering_is_deterministic() {
        for d in all_domains() {
            let a = d.render(3, &mut Rng::new(7), 32);
            let b = d.render(3, &mut Rng::new(7), 32);
            assert_eq!(a, b, "{}", d.name());
        }
    }

    #[test]
    fn lookup_by_name() {
        for n in DOMAIN_NAMES {
            assert!(domain_by_name(n).is_some());
        }
        assert!(domain_by_name("source").is_some());
        assert!(domain_by_name("nope").is_none());
    }
}
