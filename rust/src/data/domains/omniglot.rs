//! Omniglot-like domain: handwritten glyphs as 2-5 smooth strokes on a
//! light background. Stroke-dominated, near-binary statistics — the
//! opposite end of the spectrum from the photographic domains.

use super::Domain;
use crate::data::raster::Canvas;
use crate::util::rng::Rng;

pub struct Omniglot;

impl Domain for Omniglot {
    fn name(&self) -> &'static str {
        "omniglot"
    }

    fn seed(&self) -> u64 {
        0x1623
    }

    fn n_classes(&self) -> usize {
        200 // a slice of omniglot's 1623 characters
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        let s = img as f32;
        // Class identity: stroke skeleton control points in a 5x5 grid.
        let n_strokes = crng.int_range(2, 5);
        let mut strokes: Vec<Vec<(f32, f32)>> = Vec::new();
        for _ in 0..n_strokes {
            let n_pts = crng.int_range(3, 6);
            let mut pts = Vec::new();
            let mut x = crng.range(0.15, 0.85);
            let mut y = crng.range(0.15, 0.85);
            for _ in 0..n_pts {
                pts.push((x, y));
                x = (x + crng.range(-0.35, 0.35)).clamp(0.1, 0.9);
                y = (y + crng.range(-0.35, 0.35)).clamp(0.1, 0.9);
            }
            strokes.push(pts.iter().map(|&(a, b)| (a as f32, b as f32)).collect());
        }

        // Sample jitter: per-point wobble, global shift/scale, ink width.
        let mut c = Canvas::new(img, img, [0.96, 0.95, 0.92]);
        let shift_x = rng.range(-0.05, 0.05) as f32;
        let shift_y = rng.range(-0.05, 0.05) as f32;
        let scale = 0.85 + rng.range(0.0, 0.25) as f32;
        let width = 1.0 + rng.range(0.0, 1.2) as f32;
        let ink = [0.08, 0.08, 0.1];
        for stroke in &strokes {
            let jittered: Vec<(f32, f32)> = stroke
                .iter()
                .map(|&(x, y)| {
                    let jx = x + rng.range(-0.03, 0.03) as f32;
                    let jy = y + rng.range(-0.03, 0.03) as f32;
                    (
                        ((jx - 0.5) * scale + 0.5 + shift_x) * s,
                        ((jy - 0.5) * scale + 0.5 + shift_y) * s,
                    )
                })
                .collect();
            // smooth with midpoint subdivision for curvy look
            let smooth = subdivide(&jittered);
            c.polyline(&smooth, width, ink);
        }
        c.to_vec()
    }
}

fn subdivide(pts: &[(f32, f32)]) -> Vec<(f32, f32)> {
    if pts.len() < 3 {
        return pts.to_vec();
    }
    let mut out = vec![pts[0]];
    for w in pts.windows(2) {
        let mid = ((w[0].0 + w[1].0) * 0.5, (w[0].1 + w[1].1) * 0.5);
        out.push(mid);
        out.push(w[1]);
    }
    out
}
