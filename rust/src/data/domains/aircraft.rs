//! Aircraft-like domain (stands in for FGVC-Aircraft): airframe
//! silhouettes — fuselage ellipse, swept wings, tailplane — whose
//! proportions define the model variant (the class). Fine-grained: all
//! classes share the same gross layout and differ in geometry ratios.

use super::Domain;
use crate::data::raster::Canvas;
use crate::util::rng::Rng;

pub struct Aircraft;

impl Domain for Aircraft {
    fn name(&self) -> &'static str {
        "aircraft"
    }

    fn seed(&self) -> u64 {
        0xA1C
    }

    fn n_classes(&self) -> usize {
        102 // FGVC variant count
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        // Class identity: proportions + livery greys.
        let fus_len = crng.range(0.55, 0.9) as f32;
        let fus_w = crng.range(0.06, 0.14) as f32;
        let wing_span = crng.range(0.5, 0.95) as f32;
        let wing_sweep = crng.range(0.05, 0.3) as f32;
        let wing_pos = crng.range(0.35, 0.6) as f32;
        let tail_h = crng.range(0.12, 0.3) as f32;
        let body_grey = 0.55 + crng.range(0.0, 0.4) as f32;
        let wing_grey = 0.35 + crng.range(0.0, 0.4) as f32;

        let s = img as f32;
        // Sky background with slight gradient + noise.
        let mut c = Canvas::new(img, img, [0.55, 0.68, 0.85]);
        for y in 0..img {
            let f = y as f32 / s * 0.25;
            for x in 0..img {
                let p = &mut c.px[y * img + x];
                p[0] = (p[0] + f * 0.3).min(1.0);
                p[1] = (p[1] + f * 0.25).min(1.0);
            }
        }
        c.noise(rng, 3, 0.06);

        // Sample jitter: heading (left/right), position, scale.
        let flip = if rng.bool(0.5) { -1.0f32 } else { 1.0 };
        let cx = s * 0.5 + rng.range(-0.08, 0.08) as f32 * s;
        let cy = s * 0.5 + rng.range(-0.08, 0.08) as f32 * s;
        let scale = s * (0.8 + rng.range(0.0, 0.3) as f32);
        let body = [body_grey, body_grey, body_grey * 1.02];
        let wings = [wing_grey, wing_grey, wing_grey * 1.05];

        // Fuselage.
        c.ellipse(cx, cy, fus_len * scale * 0.5, fus_w * scale * 0.5, 0.0, body);
        // Nose cone.
        c.disk(cx + flip * fus_len * scale * 0.48, cy, fus_w * scale * 0.5, body);
        // Wings (swept trapezoid via two triangles, mirrored).
        let wx = cx + flip * (wing_pos - 0.5) * fus_len * scale;
        let half = wing_span * scale * 0.5;
        let sweep = wing_sweep * scale * flip;
        for dir in [-1.0f32, 1.0] {
            c.polygon(
                &[
                    (wx, cy),
                    (wx - sweep, cy + dir * half),
                    (wx - sweep - 0.12 * scale * flip, cy + dir * half),
                    (wx - 0.16 * scale * flip, cy),
                ],
                wings,
            );
        }
        // Tailplane + fin.
        let tx = cx - flip * fus_len * scale * 0.45;
        c.polygon(
            &[
                (tx, cy),
                (tx - flip * tail_h * scale * 0.6, cy - tail_h * scale),
                (tx - flip * tail_h * scale * 0.9, cy - tail_h * scale),
                (tx - flip * 0.1 * scale, cy),
            ],
            wings,
        );
        c.to_vec()
    }
}
