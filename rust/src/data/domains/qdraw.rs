//! QuickDraw-like domain: crude single-color sketches with heavy
//! sample-to-sample deformation (human doodles of the same concept vary
//! wildly). Thin strokes on white; the class fixes a sketch "program".

use super::Domain;
use crate::data::raster::Canvas;
use crate::util::rng::Rng;

pub struct QDraw;

impl Domain for QDraw {
    fn name(&self) -> &'static str {
        "qdraw"
    }

    fn seed(&self) -> u64 {
        0x9D12A0
    }

    fn n_classes(&self) -> usize {
        100 // slice of quickdraw's 345 concepts
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        // Class program: a mix of primitive sketch elements.
        let n_elems = crng.int_range(2, 4);
        let elems: Vec<(usize, f64, f64, f64)> = (0..n_elems)
            .map(|_| {
                (
                    crng.below(4),
                    crng.range(0.2, 0.8),
                    crng.range(0.2, 0.8),
                    crng.range(0.1, 0.3),
                )
            })
            .collect();

        let s = img as f32;
        let mut c = Canvas::new(img, img, [0.99, 0.99, 0.99]);
        let ink = [0.05, 0.05, 0.08];
        // Heavy jitter: every element wobbles independently.
        for &(kind, ex, ey, er) in &elems {
            let cx = (ex + rng.range(-0.08, 0.08)) as f32 * s;
            let cy = (ey + rng.range(-0.08, 0.08)) as f32 * s;
            let r = (er * (0.8 + rng.range(0.0, 0.5))) as f32 * s;
            match kind {
                0 => {
                    // wobbly circle: polyline around center
                    let n = 14;
                    let pts: Vec<(f32, f32)> = (0..=n)
                        .map(|i| {
                            let a = std::f32::consts::TAU * i as f32 / n as f32;
                            let rr = r * (1.0 + rng.range(-0.12, 0.12) as f32);
                            (cx + rr * a.cos(), cy + rr * a.sin())
                        })
                        .collect();
                    c.polyline(&pts, 1.0, ink);
                }
                1 => {
                    // zigzag
                    let n = 5;
                    let pts: Vec<(f32, f32)> = (0..n)
                        .map(|i| {
                            (
                                cx - r + 2.0 * r * i as f32 / (n - 1) as f32,
                                cy + if i % 2 == 0 { -r * 0.5 } else { r * 0.5 }
                                    + rng.range(-2.0, 2.0) as f32,
                            )
                        })
                        .collect();
                    c.polyline(&pts, 1.0, ink);
                }
                2 => {
                    // wobbly box
                    let j = |rng: &mut Rng| rng.range(-1.5, 1.5) as f32;
                    let pts = [
                        (cx - r + j(rng), cy - r + j(rng)),
                        (cx + r + j(rng), cy - r + j(rng)),
                        (cx + r + j(rng), cy + r + j(rng)),
                        (cx - r + j(rng), cy + r + j(rng)),
                        (cx - r, cy - r),
                    ];
                    c.polyline(&pts, 1.0, ink);
                }
                _ => {
                    // stroke flourish: momentum random walk
                    let mut pts = vec![(cx, cy)];
                    let mut vx = rng.range(-2.0, 2.0) as f32;
                    let mut vy = rng.range(-2.0, 2.0) as f32;
                    let (mut x, mut y) = (cx, cy);
                    for _ in 0..10 {
                        vx += rng.range(-1.0, 1.0) as f32;
                        vy += rng.range(-1.0, 1.0) as f32;
                        x = (x + vx).clamp(1.0, s - 2.0);
                        y = (y + vy).clamp(1.0, s - 2.0);
                        pts.push((x, y));
                    }
                    c.polyline(&pts, 1.0, ink);
                }
            }
        }
        c.to_vec()
    }
}
