//! DTD-like texture domain: each class is a texture *family* with fixed
//! spectral parameters (gratings, checkers, dot lattices, noise octaves,
//! cross-hatching). Purely texture-statistics dominated — no shapes.

use super::Domain;
use crate::data::raster::{hsv, Canvas};
use crate::util::rng::Rng;

pub struct Dtd;

impl Domain for Dtd {
    fn name(&self) -> &'static str {
        "dtd"
    }

    fn seed(&self) -> u64 {
        0xD7D
    }

    fn n_classes(&self) -> usize {
        47 // DTD category count
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        let family = crng.below(5);
        let base = hsv(crng.range(0.0, 6.0) as f32, 0.3 + crng.range(0.0, 0.4) as f32, 0.4 + crng.range(0.0, 0.4) as f32);
        let accent = hsv(crng.range(0.0, 6.0) as f32, 0.5, 0.75);
        let freq = crng.range(0.25, 1.2) as f32;
        let angle_c = crng.range(0.0, std::f64::consts::PI) as f32;

        let s = img as f32;
        let mut c = Canvas::new(img, img, base);
        // Sample jitter: phase, slight angle wobble, noise amplitude.
        let phase = rng.range(0.0, std::f64::consts::TAU) as f32;
        let angle = angle_c + rng.range(-0.15, 0.15) as f32;
        match family {
            0 => {
                // parallel gratings
                c.grating(freq, angle, phase, 0.8, accent);
            }
            1 => {
                // cross-hatch: two gratings
                c.grating(freq, angle, phase, 0.6, accent);
                c.grating(freq * 1.1, angle + std::f32::consts::FRAC_PI_2, phase * 0.7, 0.5, accent);
            }
            2 => {
                // checker with jittered cell size
                let cell = (2.0 + 6.0 / freq.max(0.3)) * (0.9 + rng.range(0.0, 0.2) as f32);
                c.checker(cell, accent);
                c.noise(rng, 8, 0.1);
            }
            3 => {
                // dot lattice
                let step = (3.0 + 5.0 / freq.max(0.3)) as usize;
                let r = step as f32 * (0.2 + crng.range(0.0, 0.2) as f32);
                let off = rng.below(step) as f32;
                let mut y = off;
                while y < s {
                    let mut x = off;
                    while x < s {
                        c.disk(x, y, r, accent);
                        x += step as f32;
                    }
                    y += step as f32;
                }
            }
            _ => {
                // multi-octave blotches
                c.noise(rng, 3, 0.5);
                c.noise(rng, 7, 0.35);
                c.noise(rng, 13, 0.2);
                c.grating(freq * 0.5, angle, phase, 0.2, accent);
            }
        }
        c.to_vec()
    }
}
