//! COCO-like domain: a *cluttered scene* — the class object appears among
//! random distractor shapes over a textured background, at random scale
//! and position. Context clutter, occlusion-ish overlap and small
//! object-to-image ratios mimic what makes MSCOCO the hardest Meta-Dataset
//! target.

use super::Domain;
use crate::data::raster::{hsv, rand_color, Canvas};
use crate::util::rng::Rng;

pub struct Coco;

impl Domain for Coco {
    fn name(&self) -> &'static str {
        "coco"
    }

    fn seed(&self) -> u64 {
        0xC0C0
    }

    fn n_classes(&self) -> usize {
        80 // COCO category count
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        // Class identity: target object = shape family + palette + trim.
        let shape = crng.below(5);
        let col = hsv(crng.range(0.0, 6.0) as f32, 0.75, 0.8);
        let trim = hsv(crng.range(0.0, 6.0) as f32, 0.6, 0.45);
        let elong = crng.range(0.5, 1.8) as f32;

        let s = img as f32;
        let mut c = Canvas::new(img, img, rand_scene_bg(rng));
        c.noise(rng, 5, 0.2);

        // Distractors: random shapes that do NOT depend on the class.
        let n_distract = rng.int_range(2, 5);
        for _ in 0..n_distract {
            let dcol = rand_color(rng);
            let dx = rng.range(0.1, 0.9) as f32 * s;
            let dy = rng.range(0.1, 0.9) as f32 * s;
            let dr = rng.range(0.05, 0.16) as f32 * s;
            match rng.below(3) {
                0 => c.disk(dx, dy, dr, dcol),
                1 => c.ngon(dx, dy, dr, 4, rng.range(0.0, 1.5) as f32, dcol),
                _ => c.ngon(dx, dy, dr, 3, rng.range(0.0, 2.0) as f32, dcol),
            }
        }

        // Target object at random pose/scale (small-to-medium).
        let cx = rng.range(0.2, 0.8) as f32 * s;
        let cy = rng.range(0.2, 0.8) as f32 * s;
        let r = rng.range(0.1, 0.22) as f32 * s;
        let rot = rng.range(0.0, std::f64::consts::TAU) as f32;
        match shape {
            0 => {
                c.ellipse(cx, cy, r * elong, r, rot, col);
                c.ellipse(cx, cy, r * elong * 0.5, r * 0.5, rot, trim);
            }
            1 => {
                c.ngon(cx, cy, r, 5, rot, col);
                c.disk(cx, cy, r * 0.35, trim);
            }
            2 => {
                c.ngon(cx, cy, r, 6, rot, col);
                c.ring(cx, cy, r * 0.6, r * 0.2, trim);
            }
            3 => {
                // capsule: two disks + rect
                let dx = r * elong * rot.cos();
                let dy = r * elong * rot.sin();
                c.disk(cx - dx, cy - dy, r * 0.6, col);
                c.disk(cx + dx, cy + dy, r * 0.6, col);
                c.line(cx - dx, cy - dy, cx + dx, cy + dy, r * 1.2, col);
                c.disk(cx, cy, r * 0.3, trim);
            }
            _ => {
                // star-ish: alternating radius polygon
                let pts: Vec<(f32, f32)> = (0..10)
                    .map(|i| {
                        let a = rot + std::f32::consts::TAU * i as f32 / 10.0;
                        let rr = if i % 2 == 0 { r } else { r * 0.45 };
                        (cx + rr * a.cos(), cy + rr * a.sin())
                    })
                    .collect();
                c.polygon(&pts, col);
                c.disk(cx, cy, r * 0.25, trim);
            }
        }
        c.to_vec()
    }
}

fn rand_scene_bg(rng: &mut Rng) -> [f32; 3] {
    match rng.below(3) {
        0 => [0.55, 0.62, 0.5],  // outdoor
        1 => [0.6, 0.55, 0.45],  // indoor
        _ => [0.45, 0.55, 0.65], // street
    }
}
