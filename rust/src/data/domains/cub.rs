//! CUB-200-like domain: fine-grained bird silhouettes. Body/head/beak/
//! wing geometry is shared; the class fixes plumage palette, beak and
//! tail proportions — differences are subtle, like real bird species.

use super::Domain;
use crate::data::raster::{hsv, Canvas};
use crate::util::rng::Rng;

pub struct Cub;

impl Domain for Cub {
    fn name(&self) -> &'static str {
        "cub"
    }

    fn seed(&self) -> u64 {
        0xCB200
    }

    fn n_classes(&self) -> usize {
        200 // CUB-200 class count
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        let body_col = hsv(crng.range(0.0, 6.0) as f32, 0.4 + crng.range(0.0, 0.5) as f32, 0.35 + crng.range(0.0, 0.5) as f32);
        let wing_col = hsv(crng.range(0.0, 6.0) as f32, 0.5, 0.3 + crng.range(0.0, 0.4) as f32);
        let belly_col = hsv(crng.range(0.0, 6.0) as f32, 0.25, 0.7 + crng.range(0.0, 0.3) as f32);
        let beak_len = crng.range(0.08, 0.2) as f32;
        let tail_len = crng.range(0.15, 0.35) as f32;
        let head_ratio = crng.range(0.45, 0.65) as f32;

        let s = img as f32;
        // Sky/branch background.
        let mut c = Canvas::new(img, img, [0.75, 0.82, 0.88]);
        c.noise(rng, 4, 0.1);
        // branch
        let by = s * (0.72 + rng.range(0.0, 0.1) as f32);
        c.line(0.0, by, s, by + rng.range(-3.0, 3.0) as f32, 2.5, [0.35, 0.22, 0.12]);

        let flip = if rng.bool(0.5) { -1.0f32 } else { 1.0 };
        let cx = s * 0.5 + rng.range(-0.05, 0.05) as f32 * s;
        let cy = s * 0.52 + rng.range(-0.05, 0.05) as f32 * s;
        let scale = s * (0.55 + rng.range(0.0, 0.2) as f32);

        // Tail.
        c.polygon(
            &[
                (cx - flip * scale * 0.3, cy),
                (cx - flip * scale * (0.3 + tail_len), cy - scale * 0.1),
                (cx - flip * scale * (0.3 + tail_len), cy + scale * 0.08),
            ],
            wing_col,
        );
        // Body.
        c.ellipse(cx, cy, scale * 0.33, scale * 0.22, -0.15 * flip, body_col);
        // Belly patch.
        c.ellipse(cx - flip * scale * 0.02, cy + scale * 0.08, scale * 0.22, scale * 0.12, 0.0, belly_col);
        // Head.
        let hx = cx + flip * scale * 0.32;
        let hy = cy - scale * 0.18;
        c.disk(hx, hy, scale * 0.16 * head_ratio, body_col);
        // Beak.
        c.polygon(
            &[
                (hx + flip * scale * 0.12, hy - scale * 0.03),
                (hx + flip * scale * (0.12 + beak_len), hy),
                (hx + flip * scale * 0.12, hy + scale * 0.03),
            ],
            [0.9, 0.7, 0.2],
        );
        // Eye.
        c.disk(hx + flip * scale * 0.04, hy - scale * 0.02, 1.2, [0.05, 0.05, 0.05]);
        // Wing.
        c.ellipse(cx - flip * scale * 0.05, cy - scale * 0.02, scale * 0.2, scale * 0.1, 0.35 * flip, wing_col);
        c.to_vec()
    }
}
