//! VGG-Flowers-like domain: radial petal arrangements over foliage. The
//! class fixes petal count / shape / palette; samples vary pose and
//! background. Color- and symmetry-dominated.

use super::Domain;
use crate::data::raster::{hsv, Canvas};
use crate::util::rng::Rng;

pub struct Flower;

impl Domain for Flower {
    fn name(&self) -> &'static str {
        "flower"
    }

    fn seed(&self) -> u64 {
        0xF10E
    }

    fn n_classes(&self) -> usize {
        102 // VGG-Flowers class count
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        let petals = crng.int_range(4, 12);
        let petal_hue = crng.range(0.0, 6.0) as f32;
        let petal_sat = 0.55 + crng.range(0.0, 0.45) as f32;
        let petal_len = crng.range(0.25, 0.42) as f32;
        let petal_w = crng.range(0.35, 0.8) as f32; // relative to length
        let center_hue = crng.range(0.0, 6.0) as f32;
        let double = crng.bool(0.4); // double row of petals

        let s = img as f32;
        // Foliage background.
        let mut c = Canvas::new(img, img, [0.12, 0.32 + rng.range(0.0, 0.15) as f32, 0.1]);
        c.noise(rng, 5, 0.22);

        let cx = s * 0.5 + rng.range(-0.07, 0.07) as f32 * s;
        let cy = s * 0.5 + rng.range(-0.07, 0.07) as f32 * s;
        let phase = rng.range(0.0, std::f64::consts::TAU) as f32;
        let scale = 0.85 + rng.range(0.0, 0.3) as f32;

        let rows: &[(f32, f32)] = if double { &[(1.0, 0.0), (0.62, 0.5)] } else { &[(1.0, 0.0)] };
        for &(row_scale, row_phase) in rows {
            let len = petal_len * s * scale * row_scale;
            let wid = len * petal_w * 0.5;
            let col = hsv(
                petal_hue,
                petal_sat,
                (0.75 + 0.25 * row_scale).min(1.0),
            );
            for i in 0..petals {
                let a = phase + row_phase + std::f32::consts::TAU * i as f32 / petals as f32;
                let px = cx + a.cos() * len * 0.55;
                let py = cy + a.sin() * len * 0.55;
                c.ellipse(px, py, len * 0.5, wid, a, col);
            }
        }
        c.disk(cx, cy, petal_len * s * scale * 0.28, hsv(center_hue, 0.8, 0.85));
        c.to_vec()
    }
}
