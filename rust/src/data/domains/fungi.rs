//! Fungi-like domain (stands in for FGVCx-Fungi): mushroom cap/stem
//! geometry with spot/gill texture. Classes differ in cap curvature,
//! palette and spotting — fine-grained organic shapes on forest floors.

use super::Domain;
use crate::data::raster::{hsv, Canvas};
use crate::util::rng::Rng;

pub struct Fungi;

impl Domain for Fungi {
    fn name(&self) -> &'static str {
        "fungi"
    }

    fn seed(&self) -> u64 {
        0xF51
    }

    fn n_classes(&self) -> usize {
        120 // slice of the 1394 species
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        let cap_col = hsv(crng.range(0.0, 1.4) as f32, 0.5 + crng.range(0.0, 0.5) as f32, 0.4 + crng.range(0.0, 0.55) as f32);
        let stem_col = [0.85, 0.8, 0.68];
        let cap_w = crng.range(0.25, 0.45) as f32;
        let cap_h = (crng.range(0.35, 0.8) as f32) * cap_w;
        let stem_w = crng.range(0.05, 0.12) as f32;
        let stem_h = crng.range(0.25, 0.45) as f32;
        let spots = crng.bool(0.5);
        let n_spots = crng.int_range(4, 10);
        let double = crng.bool(0.3); // a second smaller mushroom

        let s = img as f32;
        // Forest-floor background.
        let mut c = Canvas::new(img, img, [0.25, 0.2, 0.12]);
        c.noise(rng, 6, 0.25);

        let count = if double { 2 } else { 1 };
        for i in 0..count {
            let scale = if i == 0 { 1.0 } else { 0.55 };
            let cx = s * (0.5 + if i == 0 { rng.range(-0.08, 0.08) as f32 } else { rng.range(-0.3, 0.3) as f32 });
            let base_y = s * (0.82 + rng.range(-0.04, 0.04) as f32);
            let sw = stem_w * s * scale;
            let sh = stem_h * s * scale * (0.9 + rng.range(0.0, 0.2) as f32);
            let cw = cap_w * s * scale * (0.9 + rng.range(0.0, 0.2) as f32);
            let ch = cap_h * s * scale;
            // Stem.
            c.rect(cx - sw, base_y - sh, cx + sw, base_y, stem_col);
            // Cap: upper half-ellipse.
            let cap_y = base_y - sh;
            c.ellipse(cx, cap_y, cw, ch, 0.0, cap_col);
            c.rect(cx - cw, cap_y, cx + cw, cap_y + ch * 0.25, cap_col);
            // Spots.
            if spots {
                let mut srng = rng.fork(i as u64 + 100);
                for _ in 0..n_spots {
                    let a = srng.range(-1.0, 1.0) as f32;
                    let b = srng.range(-0.9, 0.1) as f32;
                    c.disk(
                        cx + a * cw * 0.8,
                        cap_y + b * ch * 0.8,
                        1.0 + srng.range(0.0, 1.5) as f32,
                        [0.95, 0.93, 0.85],
                    );
                }
            }
        }
        c.to_vec()
    }
}
