//! Traffic-sign-like domain (stands in for GTSRB): saturated border
//! shapes (ring / triangle / octagon / square) with an inner glyph.
//! Shape-and-color dominated, low texture — like real road signs.

use super::Domain;
use crate::data::raster::{hsv, rand_color, Canvas};
use crate::util::rng::Rng;

pub struct Traffic;

impl Domain for Traffic {
    fn name(&self) -> &'static str {
        "traffic"
    }

    fn seed(&self) -> u64 {
        0x7201
    }

    fn n_classes(&self) -> usize {
        43 // GTSRB's class count
    }

    fn render(&self, class: usize, rng: &mut Rng, img: usize) -> Vec<f32> {
        let mut crng = self.class_rng(class);
        // Class identity: outline family, border hue, glyph family.
        let outline = crng.below(4);
        let border = hsv(crng.range(0.0, 6.0) as f32, 0.9, 0.9);
        let inner = if crng.bool(0.5) { [1.0, 1.0, 0.95] } else { [0.12, 0.12, 0.2] };
        let glyph = crng.below(4);
        let glyph_color = if inner[0] > 0.5 { [0.1, 0.1, 0.1] } else { [0.95, 0.95, 0.9] };

        // Sample jitter: position/scale/background.
        let s = img as f32;
        let mut c = Canvas::new(img, img, rand_bg(rng));
        c.noise(rng, 4, 0.15);
        let cx = s * 0.5 + rng.range(-0.06, 0.06) as f32 * s;
        let cy = s * 0.5 + rng.range(-0.06, 0.06) as f32 * s;
        let r = s * (0.30 + rng.range(0.0, 0.08) as f32);
        let rot = rng.range(-0.12, 0.12) as f32;

        match outline {
            0 => {
                c.disk(cx, cy, r, inner);
                c.ring(cx, cy, r, r * 0.28, border);
            }
            1 => {
                c.ngon(cx, cy, r * 1.15, 3, rot - std::f32::consts::FRAC_PI_2, border);
                c.ngon(cx, cy, r * 0.78, 3, rot - std::f32::consts::FRAC_PI_2, inner);
            }
            2 => {
                c.ngon(cx, cy, r * 1.05, 8, rot, border);
                c.ngon(cx, cy, r * 0.75, 8, rot, inner);
            }
            _ => {
                c.ngon(cx, cy, r * 1.1, 4, rot + std::f32::consts::FRAC_PI_4, border);
                c.ngon(cx, cy, r * 0.8, 4, rot + std::f32::consts::FRAC_PI_4, inner);
            }
        }
        match glyph {
            0 => c.rect(cx - r * 0.45, cy - r * 0.12, cx + r * 0.45, cy + r * 0.12, glyph_color),
            1 => c.disk(cx, cy, r * 0.22, glyph_color),
            2 => {
                // arrow
                c.line(cx, cy + r * 0.4, cx, cy - r * 0.35, 2.0, glyph_color);
                c.polygon(
                    &[
                        (cx - r * 0.25, cy - r * 0.15),
                        (cx + r * 0.25, cy - r * 0.15),
                        (cx, cy - r * 0.5),
                    ],
                    glyph_color,
                );
            }
            _ => {
                c.line(cx - r * 0.35, cy - r * 0.35, cx + r * 0.35, cy + r * 0.35, 2.0, glyph_color);
                c.line(cx - r * 0.35, cy + r * 0.35, cx + r * 0.35, cy - r * 0.35, 2.0, glyph_color);
            }
        }
        c.to_vec()
    }
}

fn rand_bg(rng: &mut Rng) -> [f32; 3] {
    let base = rand_color(rng);
    [base[0] * 0.35 + 0.3, base[1] * 0.35 + 0.3, base[2] * 0.35 + 0.3]
}
