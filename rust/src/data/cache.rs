//! Shared render cache for the procedural domains.
//!
//! Every (method × domain × episode) grid cell replays the *same*
//! pre-forked episode RNG streams (`harness::parallel`), so with M
//! methods each image used to be rasterized M times; repeated table
//! runs (serial-vs-parallel comparisons, figure sweeps) re-render
//! everything again. Rasterization is by far the most expensive part of
//! episode construction (value noise + scanline fills per pixel), so the
//! cache keys a render on exactly what determines its output:
//!
//!   (domain, class, resolution, RNG stream position)
//!
//! `Domain::render` is a pure function of that tuple — class identity
//! comes from `class_rng(class)` and all sample jitter from the caller's
//! stream — so a hit can return the stored tensor *and* fast-forward the
//! caller's RNG to the exact position the skipped render would have left
//! it at. That makes caching invisible to determinism: tables are
//! bit-identical with the cache on or off, at any worker count, because
//! every downstream draw sees an unchanged stream.
//!
//! Images are stored as `Arc<[f32]>` and shared with the episodes that
//! use them, so a hit costs one pointer clone, not a tensor copy.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::domains::Domain;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct RenderKey {
    /// FNV-1a of the domain name (domains are stateless unit structs;
    /// the name plus `seed()` is their whole identity).
    domain: u64,
    class: u32,
    img: u32,
    /// RNG stream position going into the render.
    state: u64,
}

#[derive(Debug, Clone)]
struct RenderEntry {
    image: Arc<[f32]>,
    /// Stream position after the render — restored into the caller's
    /// RNG on a hit so the stream advances exactly as if it rendered.
    state_out: u64,
}

/// Cache hit/miss counters plus the current entry count.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RenderCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

/// Sharded, bounded, thread-safe render cache. See the module docs for
/// the key contract. Shards keep lock hold times short under the
/// parallel episode harness; when a shard reaches its capacity it is
/// cleared wholesale (entries are cheap to regenerate and correctness
/// never depends on residency).
pub struct RenderCache {
    shards: Vec<Mutex<HashMap<RenderKey, RenderEntry>>>,
    shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl RenderCache {
    /// `shards` is rounded up to a power of two; `shard_cap` bounds the
    /// entries per shard (total memory ≈ shards × cap × image bytes).
    pub fn new(shards: usize, shard_cap: usize) -> RenderCache {
        let n = shards.max(1).next_power_of_two();
        RenderCache {
            shards: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            shard_cap: shard_cap.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The process-wide cache the samplers use by default: 8 shards ×
    /// 4096 entries (≈ 100 MB ceiling at the testbed's 16×16 RGB —
    /// 3 KB/entry — and 4× that at 32×32; in practice a grid run keeps
    /// a few hundred entries resident).
    pub fn global() -> &'static RenderCache {
        static GLOBAL: OnceLock<RenderCache> = OnceLock::new();
        GLOBAL.get_or_init(|| RenderCache::new(8, 4096))
    }

    /// Render `class` at `img`×`img` through the cache. Must behave
    /// exactly like `domain.render(class, rng, img)` — including the
    /// caller-visible RNG advancement — whether it hits or misses.
    pub fn render(
        &self,
        domain: &dyn Domain,
        class: usize,
        rng: &mut Rng,
        img: usize,
    ) -> Arc<[f32]> {
        let key = RenderKey {
            domain: fnv1a(domain.name()),
            class: class as u32,
            img: img as u32,
            state: rng.state(),
        };
        let shard = &self.shards[self.shard_of(&key)];
        if let Some(entry) = shard.lock().unwrap().get(&key) {
            let entry = entry.clone();
            *rng = Rng::from_state(entry.state_out);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return entry.image;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let image: Arc<[f32]> = domain.render(class, rng, img).into();
        let entry = RenderEntry { image: Arc::clone(&image), state_out: rng.state() };
        let mut map = shard.lock().unwrap();
        if map.len() >= self.shard_cap {
            map.clear();
        }
        map.insert(key, entry);
        image
    }

    pub fn stats(&self) -> RenderCacheStats {
        RenderCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.shards.iter().map(|s| s.lock().unwrap().len()).sum(),
        }
    }

    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }

    fn shard_of(&self, key: &RenderKey) -> usize {
        // SplitMix64 finalizer over the mixed key fields.
        let mut z = key.state ^ key.domain ^ (((key.class as u64) << 32) | key.img as u64);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        (z ^ (z >> 31)) as usize & (self.shards.len() - 1)
    }
}

fn fnv1a(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::domains::{Omniglot, Traffic};

    #[test]
    fn hit_matches_uncached_render_and_stream_position() {
        let cache = RenderCache::new(2, 64);
        let d = Traffic;
        for seed in [1u64, 2, 3] {
            // uncached reference
            let mut r_ref = Rng::new(seed);
            let img_ref = d.render(5, &mut r_ref, 16);
            // miss, then hit, from identical stream positions
            let mut r_miss = Rng::new(seed);
            let img_miss = cache.render(&d, 5, &mut r_miss, 16);
            let mut r_hit = Rng::new(seed);
            let img_hit = cache.render(&d, 5, &mut r_hit, 16);
            assert_eq!(&img_miss[..], &img_ref[..]);
            assert_eq!(&img_hit[..], &img_ref[..]);
            assert_eq!(r_miss.state(), r_ref.state(), "miss must advance like a render");
            assert_eq!(r_hit.state(), r_ref.state(), "hit must advance like a render");
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (3, 3));
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cache = RenderCache::new(1, 64);
        let a = cache.render(&Traffic, 0, &mut Rng::new(7), 16);
        let b = cache.render(&Omniglot, 0, &mut Rng::new(7), 16);
        let c = cache.render(&Traffic, 1, &mut Rng::new(7), 16);
        let d = cache.render(&Traffic, 0, &mut Rng::new(8), 16);
        assert_ne!(&a[..], &b[..], "domain must be part of the key");
        assert_ne!(&a[..], &c[..], "class must be part of the key");
        assert_ne!(&a[..], &d[..], "rng state must be part of the key");
    }

    #[test]
    fn capacity_is_bounded() {
        let cache = RenderCache::new(1, 8);
        let d = Traffic;
        for i in 0..50u64 {
            cache.render(&d, 0, &mut Rng::new(i), 16);
        }
        assert!(cache.stats().entries <= 8, "{:?}", cache.stats());
        // still correct after evictions
        let mut r_ref = Rng::new(3);
        let reference = d.render(0, &mut r_ref, 16);
        let mut r = Rng::new(3);
        assert_eq!(&cache.render(&d, 0, &mut r, 16)[..], &reference[..]);
    }
}
