//! Episode sampler statistics — regenerates the paper's Table 5
//! (avg/SD of ways, support/query sizes, shots across sampled episodes).

use super::domains::Domain;
use super::episode::Sampler;
use crate::model::EpisodeShapes;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct DomainStats {
    pub domain: String,
    pub trials: usize,
    pub avg_ways: f64,
    pub sd_ways: f64,
    pub avg_support: f64,
    pub sd_support: f64,
    pub avg_query: f64,
    pub sd_query: f64,
    pub avg_shots: f64,
    pub sd_shots: f64,
}

pub fn mean_sd(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Sample `trials` episodes and report their summary statistics.
pub fn domain_stats(
    domain: &dyn Domain,
    shapes: &EpisodeShapes,
    trials: usize,
    seed: u64,
) -> DomainStats {
    let sampler = Sampler::new(domain, shapes);
    let mut rng = Rng::new(seed);
    let mut ways = Vec::new();
    let mut sup = Vec::new();
    let mut qry = Vec::new();
    let mut shots = Vec::new();
    for t in 0..trials {
        let mut erng = rng.fork(t as u64);
        let ep = sampler.sample(&mut erng);
        ways.push(ep.ways as f64);
        sup.push(ep.support.len() as f64);
        qry.push(ep.query.len() as f64);
        shots.extend(ep.shots.iter().map(|&s| s as f64));
    }
    let (avg_ways, sd_ways) = mean_sd(&ways);
    let (avg_support, sd_support) = mean_sd(&sup);
    let (avg_query, sd_query) = mean_sd(&qry);
    let (avg_shots, sd_shots) = mean_sd(&shots);
    DomainStats {
        domain: domain.name().to_string(),
        trials,
        avg_ways,
        sd_ways,
        avg_support,
        sd_support,
        avg_query,
        sd_query,
        avg_shots,
        sd_shots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::domains::all_domains;

    fn shapes() -> EpisodeShapes {
        EpisodeShapes {
            img: 16,
            channels: 3,
            max_ways: 10,
            max_support: 40,
            max_query: 40,
            eval_batch: 80,
            feat_dim: 8,
            cosine_tau: 10.0,
        }
    }

    #[test]
    fn mean_sd_basics() {
        let (m, s) = mean_sd(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-9);
        assert!((s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn stats_within_sampler_bounds() {
        let s = shapes();
        for d in all_domains().iter().take(3) {
            let st = domain_stats(d.as_ref(), &s, 50, 7);
            assert!(st.avg_ways >= 3.0 && st.avg_ways <= 10.0, "{st:?}");
            assert!(st.avg_support <= 40.0);
            assert!(st.avg_shots >= 1.0);
            assert!(st.sd_shots >= 0.0);
        }
    }
}
