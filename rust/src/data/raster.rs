//! Tiny software rasterizer: the substrate under the synthetic
//! cross-domain generators (DESIGN.md "Substitutions" — stands in for the
//! photographic Meta-Dataset domains).
//!
//! RGB f32 canvas in [0,1], scanline-ish primitives, value noise, and the
//! conversion to the NHWC [-1,1] tensors the AOT graphs consume.

use crate::util::rng::Rng;

pub type Color = [f32; 3];

#[derive(Debug, Clone)]
pub struct Canvas {
    pub w: usize,
    pub h: usize,
    pub px: Vec<Color>,
}

impl Canvas {
    pub fn new(w: usize, h: usize, bg: Color) -> Self {
        Canvas { w, h, px: vec![bg; w * h] }
    }

    #[inline]
    pub fn put(&mut self, x: i32, y: i32, c: Color) {
        if x >= 0 && y >= 0 && (x as usize) < self.w && (y as usize) < self.h {
            self.px[y as usize * self.w + x as usize] = c;
        }
    }

    #[inline]
    pub fn blend(&mut self, x: i32, y: i32, c: Color, alpha: f32) {
        if x >= 0 && y >= 0 && (x as usize) < self.w && (y as usize) < self.h {
            let p = &mut self.px[y as usize * self.w + x as usize];
            for i in 0..3 {
                p[i] = p[i] * (1.0 - alpha) + c[i] * alpha;
            }
        }
    }

    /// Filled disk.
    pub fn disk(&mut self, cx: f32, cy: f32, r: f32, c: Color) {
        let (x0, x1) = ((cx - r).floor() as i32, (cx + r).ceil() as i32);
        let (y0, y1) = ((cy - r).floor() as i32, (cy + r).ceil() as i32);
        for y in y0..=y1 {
            for x in x0..=x1 {
                let dx = x as f32 + 0.5 - cx;
                let dy = y as f32 + 0.5 - cy;
                if dx * dx + dy * dy <= r * r {
                    self.put(x, y, c);
                }
            }
        }
    }

    /// Ring (annulus) of thickness `t`.
    pub fn ring(&mut self, cx: f32, cy: f32, r: f32, t: f32, c: Color) {
        let ro2 = r * r;
        let ri = (r - t).max(0.0);
        let ri2 = ri * ri;
        let (x0, x1) = ((cx - r).floor() as i32, (cx + r).ceil() as i32);
        let (y0, y1) = ((cy - r).floor() as i32, (cy + r).ceil() as i32);
        for y in y0..=y1 {
            for x in x0..=x1 {
                let dx = x as f32 + 0.5 - cx;
                let dy = y as f32 + 0.5 - cy;
                let d2 = dx * dx + dy * dy;
                if d2 <= ro2 && d2 >= ri2 {
                    self.put(x, y, c);
                }
            }
        }
    }

    /// Filled axis-aligned ellipse (optionally rotated by `rot` radians).
    pub fn ellipse(&mut self, cx: f32, cy: f32, rx: f32, ry: f32, rot: f32, c: Color) {
        let r = rx.max(ry) + 1.0;
        let (x0, x1) = ((cx - r).floor() as i32, (cx + r).ceil() as i32);
        let (y0, y1) = ((cy - r).floor() as i32, (cy + r).ceil() as i32);
        let (s, co) = rot.sin_cos();
        for y in y0..=y1 {
            for x in x0..=x1 {
                let dx = x as f32 + 0.5 - cx;
                let dy = y as f32 + 0.5 - cy;
                let u = dx * co + dy * s;
                let v = -dx * s + dy * co;
                if (u / rx) * (u / rx) + (v / ry) * (v / ry) <= 1.0 {
                    self.put(x, y, c);
                }
            }
        }
    }

    /// Filled convex/concave polygon via even-odd scanline test.
    pub fn polygon(&mut self, pts: &[(f32, f32)], c: Color) {
        if pts.len() < 3 {
            return;
        }
        let ymin = pts.iter().map(|p| p.1).fold(f32::MAX, f32::min).floor() as i32;
        let ymax = pts.iter().map(|p| p.1).fold(f32::MIN, f32::max).ceil() as i32;
        // One crossing buffer for the whole fill (reused across scanlines).
        let mut xs: Vec<f32> = Vec::with_capacity(pts.len());
        for y in ymin..=ymax {
            let fy = y as f32 + 0.5;
            xs.clear();
            for i in 0..pts.len() {
                let (x1, y1) = pts[i];
                let (x2, y2) = pts[(i + 1) % pts.len()];
                if (y1 <= fy && y2 > fy) || (y2 <= fy && y1 > fy) {
                    xs.push(x1 + (fy - y1) / (y2 - y1) * (x2 - x1));
                }
            }
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for pair in xs.chunks(2) {
                if let [a, b] = pair {
                    for x in a.round() as i32..=b.round() as i32 {
                        self.put(x, y, c);
                    }
                }
            }
        }
    }

    /// Regular n-gon.
    pub fn ngon(&mut self, cx: f32, cy: f32, r: f32, n: usize, rot: f32, c: Color) {
        let pts: Vec<(f32, f32)> = (0..n)
            .map(|i| {
                let a = rot + std::f32::consts::TAU * i as f32 / n as f32;
                (cx + r * a.cos(), cy + r * a.sin())
            })
            .collect();
        self.polygon(&pts, c);
    }

    /// Thick line segment.
    pub fn line(&mut self, x1: f32, y1: f32, x2: f32, y2: f32, t: f32, c: Color) {
        let dx = x2 - x1;
        let dy = y2 - y1;
        let len = (dx * dx + dy * dy).sqrt().max(1e-3);
        let steps = (len * 2.0).ceil() as usize;
        let half = t * 0.5;
        for i in 0..=steps {
            let f = i as f32 / steps as f32;
            let px = x1 + f * dx;
            let py = y1 + f * dy;
            if half <= 0.6 {
                self.put(px.round() as i32, py.round() as i32, c);
            } else {
                self.disk(px, py, half, c);
            }
        }
    }

    pub fn polyline(&mut self, pts: &[(f32, f32)], t: f32, c: Color) {
        for w in pts.windows(2) {
            self.line(w[0].0, w[0].1, w[1].0, w[1].1, t, c);
        }
    }

    pub fn rect(&mut self, x0: f32, y0: f32, x1: f32, y1: f32, c: Color) {
        self.polygon(&[(x0, y0), (x1, y0), (x1, y1), (x0, y1)], c);
    }

    /// Additive value-noise layer with `cells` grid resolution.
    pub fn noise(&mut self, rng: &mut Rng, cells: usize, amp: f32) {
        let g = cells.max(2);
        let grid: Vec<f32> = (0..(g + 1) * (g + 1)).map(|_| rng.uniform() as f32 - 0.5).collect();
        for y in 0..self.h {
            for x in 0..self.w {
                let fx = x as f32 / self.w as f32 * g as f32;
                let fy = y as f32 / self.h as f32 * g as f32;
                let (ix, iy) = (fx as usize, fy as usize);
                let (tx, ty) = (fx - ix as f32, fy - iy as f32);
                let idx = |i: usize, j: usize| grid[j.min(g) * (g + 1) + i.min(g)];
                let v = idx(ix, iy) * (1.0 - tx) * (1.0 - ty)
                    + idx(ix + 1, iy) * tx * (1.0 - ty)
                    + idx(ix, iy + 1) * (1.0 - tx) * ty
                    + idx(ix + 1, iy + 1) * tx * ty;
                let p = &mut self.px[y * self.w + x];
                for ch in p.iter_mut() {
                    *ch = (*ch + v * amp).clamp(0.0, 1.0);
                }
            }
        }
    }

    /// Sinusoidal grating overlay (textures domain).
    pub fn grating(&mut self, freq: f32, angle: f32, phase: f32, amp: f32, c: Color) {
        let (s, co) = angle.sin_cos();
        for y in 0..self.h {
            for x in 0..self.w {
                let u = x as f32 * co + y as f32 * s;
                let v = ((u * freq + phase).sin() * 0.5 + 0.5) * amp;
                let p = &mut self.px[y * self.w + x];
                for i in 0..3 {
                    p[i] = (p[i] * (1.0 - v) + c[i] * v).clamp(0.0, 1.0);
                }
            }
        }
    }

    /// Checkerboard overlay.
    pub fn checker(&mut self, cell: f32, c: Color) {
        for y in 0..self.h {
            for x in 0..self.w {
                let cx = (x as f32 / cell) as i32;
                let cy = (y as f32 / cell) as i32;
                if (cx + cy) % 2 == 0 {
                    self.px[y * self.w + x] = c;
                }
            }
        }
    }

    /// Flatten to NHWC [-1, 1] floats (one image's worth).
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.w * self.h * 3);
        for p in &self.px {
            for ch in p {
                out.push(ch * 2.0 - 1.0);
            }
        }
        out
    }
}

/// Random saturated color.
pub fn rand_color(rng: &mut Rng) -> Color {
    let h = rng.uniform() as f32 * 6.0;
    let s = 0.5 + 0.5 * rng.uniform() as f32;
    let v = 0.5 + 0.5 * rng.uniform() as f32;
    hsv(h, s, v)
}

/// HSV (h in [0,6)) to RGB.
pub fn hsv(h: f32, s: f32, v: f32) -> Color {
    let i = h.floor() as i32 % 6;
    let f = h - h.floor();
    let p = v * (1.0 - s);
    let q = v * (1.0 - f * s);
    let t = v * (1.0 - (1.0 - f) * s);
    match i {
        0 => [v, t, p],
        1 => [q, v, p],
        2 => [p, v, t],
        3 => [p, q, v],
        4 => [t, p, v],
        _ => [v, p, q],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canvas_bounds_are_safe() {
        let mut c = Canvas::new(8, 8, [0.0; 3]);
        c.put(-5, -5, [1.0; 3]);
        c.put(100, 100, [1.0; 3]);
        c.disk(-10.0, -10.0, 3.0, [1.0; 3]);
        c.line(-5.0, -5.0, 50.0, 50.0, 2.0, [1.0; 3]);
        // no panic = pass; center pixel must be touched by the line
        assert!(c.px[4 * 8 + 4][0] > 0.0);
    }

    #[test]
    fn disk_fills_center_not_corner() {
        let mut c = Canvas::new(16, 16, [0.0; 3]);
        c.disk(8.0, 8.0, 4.0, [1.0, 0.0, 0.0]);
        assert_eq!(c.px[8 * 16 + 8], [1.0, 0.0, 0.0]);
        assert_eq!(c.px[0], [0.0, 0.0, 0.0]);
    }

    #[test]
    fn polygon_even_odd() {
        let mut c = Canvas::new(16, 16, [0.0; 3]);
        c.polygon(&[(2.0, 2.0), (13.0, 2.0), (13.0, 13.0), (2.0, 13.0)], [0.0, 1.0, 0.0]);
        assert_eq!(c.px[8 * 16 + 8], [0.0, 1.0, 0.0]);
        assert_eq!(c.px[0], [0.0, 0.0, 0.0]);
    }

    #[test]
    fn to_vec_range_and_layout() {
        let mut c = Canvas::new(4, 4, [0.5; 3]);
        c.put(0, 0, [1.0, 0.0, 0.5]);
        let v = c.to_vec();
        assert_eq!(v.len(), 4 * 4 * 3);
        assert!((v[0] - 1.0).abs() < 1e-6); // R of (0,0)
        assert!((v[1] + 1.0).abs() < 1e-6); // G of (0,0)
        assert!(v.iter().all(|x| (-1.0..=1.0).contains(x)));
    }

    #[test]
    fn noise_stays_in_range() {
        let mut c = Canvas::new(12, 12, [0.5; 3]);
        let mut rng = Rng::new(9);
        c.noise(&mut rng, 4, 0.8);
        assert!(c.px.iter().all(|p| p.iter().all(|&v| (0.0..=1.0).contains(&v))));
    }

    #[test]
    fn deterministic_given_seed() {
        let render = |seed| {
            let mut c = Canvas::new(8, 8, [0.1; 3]);
            let mut rng = Rng::new(seed);
            c.noise(&mut rng, 3, 0.5);
            c.to_vec()
        };
        assert_eq!(render(5), render(5));
        assert_ne!(render(5), render(6));
    }
}
