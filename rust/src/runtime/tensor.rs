//! Host-side f32 tensor: the unit of exchange with the PJRT executables.

use anyhow::{anyhow, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor { data, dims }
    }

    pub fn zeros(dims: &[usize]) -> Self {
        Tensor { data: vec![0.0; dims.iter().product()], dims: dims.to_vec() }
    }

    pub fn ones(dims: &[usize]) -> Self {
        Tensor { data: vec![1.0; dims.iter().product()], dims: dims.to_vec() }
    }

    pub fn scalar1(v: f32) -> Self {
        Tensor { data: vec![v], dims: vec![1] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims).map_err(|e| anyhow!("reshape to {:?}: {e}", self.dims))
    }

    pub fn from_literal(lit: xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))?;
        Ok(Tensor { data, dims })
    }

    /// Mean of all elements (for quick metrics/debugging).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn first(&self) -> f32 {
        self.data.first().copied().unwrap_or(0.0)
    }
}
