//! Artifact discovery: locates the `artifacts/` directory produced by
//! `make artifacts` and resolves per-architecture file sets.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

/// Paths of one architecture's artifact family.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub arch: String,
    pub fwd: PathBuf,
    pub fisher: PathBuf,
    pub step: PathBuf,
    pub meta: PathBuf,
    pub weights: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ArtifactStore {
    pub dir: PathBuf,
}

impl ArtifactStore {
    /// Use `dir` if given, else $TINYTRAIN_ARTIFACTS, else ./artifacts
    /// (searching upward from the current dir so tests/benches work from
    /// target subdirectories).
    pub fn discover(dir: Option<&str>) -> Result<Self> {
        if let Some(d) = dir {
            return Self::at(Path::new(d));
        }
        if let Ok(d) = std::env::var("TINYTRAIN_ARTIFACTS") {
            return Self::at(Path::new(&d));
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let candidate = cur.join("artifacts");
            if candidate.join("manifest.json").exists() {
                return Ok(ArtifactStore { dir: candidate });
            }
            if !cur.pop() {
                break;
            }
        }
        Err(anyhow!(
            "artifacts/manifest.json not found — run `make artifacts` first \
             (or set TINYTRAIN_ARTIFACTS)"
        ))
    }

    pub fn at(dir: &Path) -> Result<Self> {
        if !dir.join("manifest.json").exists() {
            return Err(anyhow!(
                "{} has no manifest.json — run `make artifacts`",
                dir.display()
            ));
        }
        Ok(ArtifactStore { dir: dir.to_path_buf() })
    }

    pub fn model(&self, arch: &str) -> ModelArtifacts {
        ModelArtifacts {
            arch: arch.to_string(),
            fwd: self.dir.join(format!("{arch}_fwd.hlo.txt")),
            fisher: self.dir.join(format!("{arch}_fisher.hlo.txt")),
            step: self.dir.join(format!("{arch}_step.hlo.txt")),
            meta: self.dir.join(format!("{arch}_meta.json")),
            weights: self.dir.join(format!("weights_{arch}.bin")),
        }
    }

    pub fn kernel_smoke(&self) -> PathBuf {
        self.dir.join("kernel_smoke.hlo.txt")
    }
}
