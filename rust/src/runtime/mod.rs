//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate. Python never runs
//! here — `make artifacts` produced the HLO text at build time, and this
//! module compiles it once per process (executables are cached) and then
//! serves the L3 hot path.
//!
//! Interchange format is HLO *text*: jax >= 0.5 serialises HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

mod artifacts;
mod tensor;

pub use artifacts::{ArtifactStore, ModelArtifacts};
pub use tensor::Tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// A compiled executable plus its host-facing metadata.
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Exec {
    /// Execute on host tensors; returns the flattened tuple elements.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("building inputs for {}", self.name))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        // Graphs are lowered with return_tuple=True.
        let parts = lit.to_tuple().map_err(|e| anyhow!("untupling {}: {e}", self.name))?;
        parts.into_iter().map(Tensor::from_literal).collect()
    }

    /// Execute with device-resident inputs (hot path: avoids host copies
    /// of unchanged operands like theta/m/v between steps).
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let result = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("executing {} (buffers)", self.name))?;
        Ok(result.into_iter().next().unwrap_or_default())
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// PJRT CPU client + executable cache keyed by artifact path.
///
/// Cheap to clone (Rc internals): `ModelEngine` holds a clone so it can
/// compile its graphs lazily — analytic experiments read only metadata
/// and never pay the compile time.
#[derive(Clone)]
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Rc<Mutex<HashMap<PathBuf, std::sync::Arc<Exec>>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, cache: Rc::new(Mutex::new(HashMap::new())) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Exec>> {
        if let Some(exec) = self.cache.lock().unwrap().get(path) {
            return Ok(exec.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        let exec = std::sync::Arc::new(Exec {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        });
        self.cache.lock().unwrap().insert(path.to_path_buf(), exec.clone());
        Ok(exec)
    }

    /// Move a host tensor to a device-resident buffer.
    pub fn to_device(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
            .map_err(|e| anyhow!("host->device transfer: {e}"))
    }

    /// Fetch a device buffer back to a host tensor.
    pub fn to_host(&self, b: &xla::PjRtBuffer) -> Result<Tensor> {
        let lit = b.to_literal_sync().map_err(|e| anyhow!("device->host transfer: {e}"))?;
        Tensor::from_literal(lit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip_shapes() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dims, vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let t2 = Tensor::from_literal(lit).unwrap();
        assert_eq!(t2.dims, vec![2, 3]);
        assert_eq!(t2.data, t.data);
    }
}
