//! TinyTrain (ICML 2024) — resource-aware task-adaptive sparse training at
//! the data-scarce edge, reproduced as a three-layer rust + JAX + Pallas
//! stack (see DESIGN.md).
//!
//! Layer map:
//! - L3 (this crate): the on-device training coordinator. Its public
//!   surface is the session/backend pair in [`coordinator`]:
//!   `AdaptationSession` (builder-style; owns the Algorithm-1 episode
//!   lifecycle: selection → mask → sparse fine-tuning with pseudo-query
//!   refresh → query eval) over the `AdaptationBackend` trait, whose
//!   impls are `HostBackend` / `DeviceBackend` (PJRT: host round-trip
//!   vs. device-resident state — the measured hot path) and
//!   `AnalyticBackend` (artifact-free, for selection/accounting logic
//!   without PJRT). Around it: episodic data ([`data`]), Fisher
//!   aggregation + the multi-objective criterion + budgeted selection
//!   ([`coordinator`]), analytic memory/compute accounting
//!   ([`accounting`]), device latency simulation ([`devices`]), the
//!   experiment harness ([`harness`]) and the multi-tenant serving tier
//!   ([`serve`]: shared-base + per-tenant masked-delta overlays behind
//!   a fair bounded work queue — `tinytrain serve`).
//! - L2/L1 (python/compile, build-time only): JAX backbones + Pallas
//!   kernels, AOT-lowered to the HLO artifacts [`runtime`] executes.
//!
//! # MCU envelope (`no_std`)
//!
//! With `--no-default-features --features alloc` the crate builds
//! `no_std + alloc`: only the decision core is compiled — [`accounting`]
//! (CostLedger, byte pricing), the [`coordinator`] selection / mask /
//! policy-search / analytic step-and-embed math, [`model`] metadata and
//! parameter stores, and the no_std-safe [`util`] subset (RNG, pooled
//! buffers, soft float math). Host-only tiers ([`data`], [`devices`],
//! [`harness`], [`metrics`], [`runtime`], [`serve`], CLI, benches) need
//! the default-on `std` feature. `rust/ci_size_check.sh` links the core
//! into `examples/core_footprint.rs` under the `embedded` profile and
//! gates its section sizes (SIZE_core.json) in CI.
//!
//! Tier-1 verification is `rust/ci.sh` (fmt + clippy + build + test);
//! PJRT-dependent integration tests self-skip when the workspace is
//! built against the stub `xla` backend in `vendor/`.

#![cfg_attr(not(feature = "std"), no_std)]

#[cfg(not(feature = "alloc"))]
compile_error!("tinytrain requires at least the `alloc` feature (enable `alloc` or `std`)");

extern crate alloc;

pub mod accounting;
pub mod coordinator;
#[cfg(feature = "std")]
pub mod data;
#[cfg(feature = "std")]
pub mod devices;
#[cfg(feature = "std")]
pub mod harness;
#[cfg(feature = "std")]
pub mod metrics;
pub mod model;
#[cfg(feature = "std")]
pub mod net;
#[cfg(feature = "std")]
pub mod runtime;
#[cfg(feature = "std")]
pub mod serve;
pub mod util;
