//! TinyTrain (ICML 2024) — resource-aware task-adaptive sparse training at
//! the data-scarce edge, reproduced as a three-layer rust + JAX + Pallas
//! stack (see DESIGN.md).
//!
//! Layer map:
//! - L3 (this crate): on-device training coordinator — episodes, Fisher
//!   aggregation, the multi-objective criterion, dynamic layer/channel
//!   selection, sparse fine-tuning, baselines, accounting, device sim.
//! - L2/L1 (python/compile, build-time only): JAX backbones + Pallas
//!   kernels, AOT-lowered to the HLO artifacts `runtime` executes.

pub mod accounting;
pub mod coordinator;
pub mod data;
pub mod devices;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod util;
