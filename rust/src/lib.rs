//! TinyTrain (ICML 2024) — resource-aware task-adaptive sparse training at
//! the data-scarce edge, reproduced as a three-layer rust + JAX + Pallas
//! stack (see DESIGN.md).
//!
//! Layer map:
//! - L3 (this crate): the on-device training coordinator. Its public
//!   surface is the session/backend pair in [`coordinator`]:
//!   `AdaptationSession` (builder-style; owns the Algorithm-1 episode
//!   lifecycle: selection → mask → sparse fine-tuning with pseudo-query
//!   refresh → query eval) over the `AdaptationBackend` trait, whose
//!   impls are `HostBackend` / `DeviceBackend` (PJRT: host round-trip
//!   vs. device-resident state — the measured hot path) and
//!   `AnalyticBackend` (artifact-free, for selection/accounting logic
//!   without PJRT). Around it: episodic data ([`data`]), Fisher
//!   aggregation + the multi-objective criterion + budgeted selection
//!   ([`coordinator`]), analytic memory/compute accounting
//!   ([`accounting`]), device latency simulation ([`devices`]), the
//!   experiment harness ([`harness`]) and the multi-tenant serving tier
//!   ([`serve`]: shared-base + per-tenant masked-delta overlays behind
//!   a fair bounded work queue — `tinytrain serve`).
//! - L2/L1 (python/compile, build-time only): JAX backbones + Pallas
//!   kernels, AOT-lowered to the HLO artifacts [`runtime`] executes.
//!
//! Tier-1 verification is `rust/ci.sh` (fmt + clippy + build + test);
//! PJRT-dependent integration tests self-skip when the workspace is
//! built against the stub `xla` backend in `vendor/`.

pub mod accounting;
pub mod coordinator;
pub mod data;
pub mod devices;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod util;
