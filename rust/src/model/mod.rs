//! Model metadata and parameter state.
//!
//! The build pipeline's `<arch>_meta.json` is the single source of truth
//! for layer tables (both the runnable `scaled` flavour and the analytic
//! `paper` flavour), the flat-theta packing, and the episode shape
//! constants. This module parses it and manages the mutable training
//! state (theta / Adam moments) the coordinator feeds to the AOT step
//! graph.

mod meta;
mod params;

pub use meta::{
    ArchFlavor, BlockInfo, EpisodeShapes, FisherSegment, LayerInfo, ModelMeta, ParamEntry,
};
pub use params::ParamStore;
