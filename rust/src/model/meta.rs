//! Parsed form of `<arch>_meta.json`.

use std::path::Path;

use anyhow::Result;

use crate::util::jsonio::Json;

/// One conv layer — the unit of TinyTrain's layer selection.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    pub kind: String, // stem | pw | dw | head
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub act: bool,
    pub in_hw: usize,
    pub out_hw: usize,
    pub block: i64, // -1 for stem/head
    pub weight_params: usize,
    pub params: usize,
    pub macs: usize,
    pub act_elems: usize,
}

#[derive(Debug, Clone)]
pub struct BlockInfo {
    pub idx: usize,
    pub cin: usize,
    pub cout: usize,
    pub expand: usize,
    pub k: usize,
    pub stride: usize,
    pub in_hw: usize,
    pub out_hw: usize,
    pub skip: bool,
    pub conv_ids: Vec<usize>,
}

/// One flavour of an architecture (scaled = runnable, paper = analytic).
#[derive(Debug, Clone)]
pub struct ArchFlavor {
    pub img: usize,
    pub feat_dim: usize,
    pub layers: Vec<LayerInfo>,
    pub blocks: Vec<BlockInfo>,
    pub total_params: usize,
    pub total_macs: usize,
}

/// One tensor inside the flat theta vector.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub role: String, // weight | gamma | beta | adapter_w | adapter_b
    pub layer: usize, // conv index, or block index for adapter_*
    pub mask_axis: usize,
}

/// Static episode shape constants shared with the AOT graphs.
#[derive(Debug, Clone)]
pub struct EpisodeShapes {
    pub img: usize,
    pub channels: usize,
    pub max_ways: usize,
    pub max_support: usize,
    pub max_query: usize,
    pub eval_batch: usize,
    pub feat_dim: usize,
    pub cosine_tau: f64,
}

/// Fisher output segment for one conv layer.
#[derive(Debug, Clone)]
pub struct FisherSegment {
    pub layer: usize,
    pub name: String,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub arch: String,
    pub scaled: ArchFlavor,
    pub paper: ArchFlavor,
    pub entries: Vec<ParamEntry>,
    pub total_theta: usize,
    pub fisher_len: usize,
    pub fisher_segments: Vec<FisherSegment>,
    pub shapes: EpisodeShapes,
}

fn parse_layer(j: &Json) -> Result<LayerInfo> {
    Ok(LayerInfo {
        name: j.str_of("name")?,
        kind: j.str_of("kind")?,
        cin: j.usize_of("cin")?,
        cout: j.usize_of("cout")?,
        k: j.usize_of("k")?,
        stride: j.usize_of("stride")?,
        act: j.bool_of("act")?,
        in_hw: j.usize_of("in_hw")?,
        out_hw: j.usize_of("out_hw")?,
        block: j.i64_of("block")?,
        weight_params: j.usize_of("weight_params")?,
        params: j.usize_of("params")?,
        macs: j.usize_of("macs")?,
        act_elems: j.usize_of("act_elems")?,
    })
}

fn parse_block(j: &Json) -> Result<BlockInfo> {
    Ok(BlockInfo {
        idx: j.usize_of("idx")?,
        cin: j.usize_of("cin")?,
        cout: j.usize_of("cout")?,
        expand: j.usize_of("expand")?,
        k: j.usize_of("k")?,
        stride: j.usize_of("stride")?,
        in_hw: j.usize_of("in_hw")?,
        out_hw: j.usize_of("out_hw")?,
        skip: j.bool_of("skip")?,
        conv_ids: j
            .arr_of("conv_ids")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect(),
    })
}

fn parse_flavor(j: &Json) -> Result<ArchFlavor> {
    Ok(ArchFlavor {
        img: j.usize_of("img")?,
        feat_dim: j.usize_of("feat_dim")?,
        layers: j.arr_of("layers")?.iter().map(parse_layer).collect::<Result<_>>()?,
        blocks: j.arr_of("blocks")?.iter().map(parse_block).collect::<Result<_>>()?,
        total_params: j.usize_of("total_params")?,
        total_macs: j.usize_of("total_macs")?,
    })
}

impl ModelMeta {
    pub fn load(path: &Path) -> Result<ModelMeta> {
        let j = Json::from_file(&path.to_string_lossy())?;
        let flavors = j.req("flavors")?;
        let shapes = j.req("shapes")?;
        Ok(ModelMeta {
            arch: j.str_of("arch")?,
            scaled: parse_flavor(flavors.req("scaled")?)?,
            paper: parse_flavor(flavors.req("paper")?)?,
            entries: j
                .arr_of("param_entries")?
                .iter()
                .map(|e| {
                    Ok(ParamEntry {
                        name: e.str_of("name")?,
                        shape: e
                            .arr_of("shape")?
                            .iter()
                            .map(|v| v.as_usize().unwrap_or(0))
                            .collect(),
                        offset: e.usize_of("offset")?,
                        size: e.usize_of("size")?,
                        role: e.str_of("role")?,
                        layer: e.usize_of("layer")?,
                        mask_axis: e.usize_of("mask_axis")?,
                    })
                })
                .collect::<Result<_>>()?,
            total_theta: j.usize_of("total_theta")?,
            fisher_len: j.usize_of("fisher_len")?,
            fisher_segments: j
                .arr_of("fisher_segments")?
                .iter()
                .map(|e| {
                    Ok(FisherSegment {
                        layer: e.usize_of("layer")?,
                        name: e.str_of("name")?,
                        offset: e.usize_of("offset")?,
                        size: e.usize_of("size")?,
                    })
                })
                .collect::<Result<_>>()?,
            shapes: EpisodeShapes {
                img: shapes.usize_of("img")?,
                channels: shapes.usize_of("channels")?,
                max_ways: shapes.usize_of("max_ways")?,
                max_support: shapes.usize_of("max_support")?,
                max_query: shapes.usize_of("max_query")?,
                eval_batch: shapes.usize_of("eval_batch")?,
                feat_dim: shapes.usize_of("feat_dim")?,
                cosine_tau: shapes.f64_of("cosine_tau")?,
            },
        })
    }

    /// Param entries belonging to conv layer `layer` (not adapters).
    pub fn layer_entries(&self, layer: usize) -> impl Iterator<Item = &ParamEntry> {
        self.entries
            .iter()
            .filter(move |e| !e.role.starts_with("adapter") && e.layer == layer)
    }

    /// Adapter entries of block `block`.
    pub fn adapter_entries(&self, block: usize) -> impl Iterator<Item = &ParamEntry> {
        self.entries
            .iter()
            .filter(move |e| e.role.starts_with("adapter") && e.layer == block)
    }

    /// Index of the head layer (the `LastLayer` baseline's target).
    pub fn head_layer(&self) -> usize {
        self.scaled.layers.len() - 1
    }
}
