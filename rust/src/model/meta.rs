//! Parsed form of `<arch>_meta.json`.
//!
//! The struct definitions and the [`ModelMeta::synthetic`] constructor
//! are `no_std + alloc` (the MCU build ships metadata baked in, or
//! receives it pre-parsed); JSON parsing from disk is std-only.

#[cfg(feature = "std")]
use std::path::Path;

use alloc::format;
use alloc::string::String;
use alloc::{vec, vec::Vec};

#[cfg(feature = "std")]
use anyhow::Result;

#[cfg(feature = "std")]
use crate::util::jsonio::Json;

/// One conv layer — the unit of TinyTrain's layer selection.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    pub kind: String, // stem | pw | dw | head
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub act: bool,
    pub in_hw: usize,
    pub out_hw: usize,
    pub block: i64, // -1 for stem/head
    pub weight_params: usize,
    pub params: usize,
    pub macs: usize,
    pub act_elems: usize,
}

#[derive(Debug, Clone)]
pub struct BlockInfo {
    pub idx: usize,
    pub cin: usize,
    pub cout: usize,
    pub expand: usize,
    pub k: usize,
    pub stride: usize,
    pub in_hw: usize,
    pub out_hw: usize,
    pub skip: bool,
    pub conv_ids: Vec<usize>,
}

/// One flavour of an architecture (scaled = runnable, paper = analytic).
#[derive(Debug, Clone)]
pub struct ArchFlavor {
    pub img: usize,
    pub feat_dim: usize,
    pub layers: Vec<LayerInfo>,
    pub blocks: Vec<BlockInfo>,
    pub total_params: usize,
    pub total_macs: usize,
}

/// One tensor inside the flat theta vector.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub role: String, // weight | gamma | beta | adapter_w | adapter_b
    pub layer: usize, // conv index, or block index for adapter_*
    pub mask_axis: usize,
}

/// Static episode shape constants shared with the AOT graphs.
#[derive(Debug, Clone)]
pub struct EpisodeShapes {
    pub img: usize,
    pub channels: usize,
    pub max_ways: usize,
    pub max_support: usize,
    pub max_query: usize,
    pub eval_batch: usize,
    pub feat_dim: usize,
    pub cosine_tau: f64,
}

/// Fisher output segment for one conv layer.
#[derive(Debug, Clone)]
pub struct FisherSegment {
    pub layer: usize,
    pub name: String,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub arch: String,
    pub scaled: ArchFlavor,
    pub paper: ArchFlavor,
    pub entries: Vec<ParamEntry>,
    pub total_theta: usize,
    pub fisher_len: usize,
    pub fisher_segments: Vec<FisherSegment>,
    pub shapes: EpisodeShapes,
}

#[cfg(feature = "std")]
fn parse_layer(j: &Json) -> Result<LayerInfo> {
    Ok(LayerInfo {
        name: j.str_of("name")?,
        kind: j.str_of("kind")?,
        cin: j.usize_of("cin")?,
        cout: j.usize_of("cout")?,
        k: j.usize_of("k")?,
        stride: j.usize_of("stride")?,
        act: j.bool_of("act")?,
        in_hw: j.usize_of("in_hw")?,
        out_hw: j.usize_of("out_hw")?,
        block: j.i64_of("block")?,
        weight_params: j.usize_of("weight_params")?,
        params: j.usize_of("params")?,
        macs: j.usize_of("macs")?,
        act_elems: j.usize_of("act_elems")?,
    })
}

#[cfg(feature = "std")]
fn parse_block(j: &Json) -> Result<BlockInfo> {
    Ok(BlockInfo {
        idx: j.usize_of("idx")?,
        cin: j.usize_of("cin")?,
        cout: j.usize_of("cout")?,
        expand: j.usize_of("expand")?,
        k: j.usize_of("k")?,
        stride: j.usize_of("stride")?,
        in_hw: j.usize_of("in_hw")?,
        out_hw: j.usize_of("out_hw")?,
        skip: j.bool_of("skip")?,
        conv_ids: j
            .arr_of("conv_ids")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect(),
    })
}

#[cfg(feature = "std")]
fn parse_flavor(j: &Json) -> Result<ArchFlavor> {
    Ok(ArchFlavor {
        img: j.usize_of("img")?,
        feat_dim: j.usize_of("feat_dim")?,
        layers: j.arr_of("layers")?.iter().map(parse_layer).collect::<Result<_>>()?,
        blocks: j.arr_of("blocks")?.iter().map(parse_block).collect::<Result<_>>()?,
        total_params: j.usize_of("total_params")?,
        total_macs: j.usize_of("total_macs")?,
    })
}

impl ModelMeta {
    #[cfg(feature = "std")]
    pub fn load(path: &Path) -> Result<ModelMeta> {
        let j = Json::from_file(&path.to_string_lossy())?;
        let flavors = j.req("flavors")?;
        let shapes = j.req("shapes")?;
        Ok(ModelMeta {
            arch: j.str_of("arch")?,
            scaled: parse_flavor(flavors.req("scaled")?)?,
            paper: parse_flavor(flavors.req("paper")?)?,
            entries: j
                .arr_of("param_entries")?
                .iter()
                .map(|e| {
                    Ok(ParamEntry {
                        name: e.str_of("name")?,
                        shape: e
                            .arr_of("shape")?
                            .iter()
                            .map(|v| v.as_usize().unwrap_or(0))
                            .collect(),
                        offset: e.usize_of("offset")?,
                        size: e.usize_of("size")?,
                        role: e.str_of("role")?,
                        layer: e.usize_of("layer")?,
                        mask_axis: e.usize_of("mask_axis")?,
                    })
                })
                .collect::<Result<_>>()?,
            total_theta: j.usize_of("total_theta")?,
            fisher_len: j.usize_of("fisher_len")?,
            fisher_segments: j
                .arr_of("fisher_segments")?
                .iter()
                .map(|e| {
                    Ok(FisherSegment {
                        layer: e.usize_of("layer")?,
                        name: e.str_of("name")?,
                        offset: e.usize_of("offset")?,
                        size: e.usize_of("size")?,
                    })
                })
                .collect::<Result<_>>()?,
            shapes: EpisodeShapes {
                img: shapes.usize_of("img")?,
                channels: shapes.usize_of("channels")?,
                max_ways: shapes.usize_of("max_ways")?,
                max_support: shapes.usize_of("max_support")?,
                max_query: shapes.usize_of("max_query")?,
                eval_batch: shapes.usize_of("eval_batch")?,
                feat_dim: shapes.usize_of("feat_dim")?,
                cosine_tau: shapes.f64_of("cosine_tau")?,
            },
        })
    }

    /// Fully consistent synthetic metadata — the layer table, theta
    /// packing, fisher segments and episode shapes all agree — for
    /// benches and PJRT-free tests that need more than a toy two-layer
    /// arch. Topology is mcunet-like: stem + `n_blocks` inverted-residual
    /// blocks (pw-expand / dw / pw-project, widths growing with depth,
    /// the deeper half at reduced resolution) + head. No adapters, so
    /// TinyTL-family masks degrade to head-only on this meta.
    pub fn synthetic(n_blocks: usize) -> ModelMeta {
        let img = 16usize;
        let channels = 3usize;
        let feat_dim = 16usize;

        struct Builder {
            layers: Vec<LayerInfo>,
            entries: Vec<ParamEntry>,
            fisher_segments: Vec<FisherSegment>,
            offset: usize,
            fisher_off: usize,
        }
        impl Builder {
            /// Push one conv layer plus its weight/gamma/beta entries and
            /// fisher segment; returns the layer index.
            #[allow(clippy::too_many_arguments)]
            fn layer(
                &mut self,
                name: &str,
                kind: &str,
                cin: usize,
                cout: usize,
                k: usize,
                hw: usize,
                block: i64,
            ) -> usize {
                let idx = self.layers.len();
                let depthwise = kind == "dw";
                let weight_params = if depthwise { k * k * cout } else { k * k * cin * cout };
                let macs = hw * hw * cout * k * k * if depthwise { 1 } else { cin };
                self.layers.push(LayerInfo {
                    name: name.into(),
                    kind: kind.into(),
                    cin,
                    cout,
                    k,
                    stride: 1,
                    act: true,
                    in_hw: hw,
                    out_hw: hw,
                    block,
                    weight_params,
                    params: weight_params + 2 * cout,
                    macs,
                    act_elems: hw * hw * cout,
                });
                let w_shape = if depthwise { vec![k, k, 1, cout] } else { vec![k, k, cin, cout] };
                for (role, shape) in
                    [("weight", w_shape), ("gamma", vec![cout]), ("beta", vec![cout])]
                {
                    let size: usize = shape.iter().product();
                    let mask_axis = shape.len() - 1;
                    self.entries.push(ParamEntry {
                        name: format!("{name}.{role}"),
                        shape,
                        offset: self.offset,
                        size,
                        role: role.into(),
                        layer: idx,
                        mask_axis,
                    });
                    self.offset += size;
                }
                self.fisher_segments.push(FisherSegment {
                    layer: idx,
                    name: name.into(),
                    offset: self.fisher_off,
                    size: cout,
                });
                self.fisher_off += cout;
                idx
            }
        }

        let mut b = Builder {
            layers: Vec::new(),
            entries: Vec::new(),
            fisher_segments: Vec::new(),
            offset: 0,
            fisher_off: 0,
        };
        b.layer("stem", "stem", channels, 8, 3, img, -1);
        let mut blocks = Vec::new();
        let mut cin = 8usize;
        for bi in 0..n_blocks {
            let cout = 8 + 4 * bi;
            let hidden = cin * 2;
            let hw = if bi < n_blocks / 2 { img } else { img / 2 };
            let e = b.layer(&format!("b{bi}.expand"), "pw", cin, hidden, 1, hw, bi as i64);
            let d = b.layer(&format!("b{bi}.dw"), "dw", hidden, hidden, 3, hw, bi as i64);
            let p = b.layer(&format!("b{bi}.project"), "pw", hidden, cout, 1, hw, bi as i64);
            blocks.push(BlockInfo {
                idx: bi,
                cin,
                cout,
                expand: 2,
                k: 3,
                stride: 1,
                in_hw: hw,
                out_hw: hw,
                skip: cin == cout,
                conv_ids: vec![e, d, p],
            });
            cin = cout;
        }
        b.layer("head", "head", cin, feat_dim, 1, img / 2, -1);

        let total_params: usize = b.layers.iter().map(|l| l.params).sum();
        let total_macs: usize = b.layers.iter().map(|l| l.macs).sum();
        let flavor = ArchFlavor {
            img,
            feat_dim,
            layers: b.layers,
            blocks,
            total_params,
            total_macs,
        };
        ModelMeta {
            arch: format!("synthetic{n_blocks}"),
            scaled: flavor.clone(),
            paper: flavor,
            entries: b.entries,
            total_theta: b.offset,
            fisher_len: b.fisher_off,
            fisher_segments: b.fisher_segments,
            shapes: EpisodeShapes {
                img,
                channels,
                max_ways: 4,
                max_support: 8,
                max_query: 8,
                eval_batch: 16,
                feat_dim,
                cosine_tau: 10.0,
            },
        }
    }

    /// Param entries belonging to conv layer `layer` (not adapters).
    pub fn layer_entries(&self, layer: usize) -> impl Iterator<Item = &ParamEntry> {
        self.entries
            .iter()
            .filter(move |e| !e.role.starts_with("adapter") && e.layer == layer)
    }

    /// Adapter entries of block `block`.
    pub fn adapter_entries(&self, block: usize) -> impl Iterator<Item = &ParamEntry> {
        self.entries
            .iter()
            .filter(move |e| e.role.starts_with("adapter") && e.layer == block)
    }

    /// Index of the head layer (the `LastLayer` baseline's target).
    pub fn head_layer(&self) -> usize {
        self.scaled.layers.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_meta_is_self_consistent() {
        let meta = ModelMeta::synthetic(4);
        assert_eq!(meta.scaled.layers.len(), 2 + 3 * 4);
        assert_eq!(meta.scaled.blocks.len(), 4);
        // entries tile theta contiguously
        let mut cursor = 0;
        for e in &meta.entries {
            assert_eq!(e.offset, cursor, "{} not contiguous", e.name);
            assert_eq!(e.size, e.shape.iter().product::<usize>());
            cursor += e.size;
        }
        assert_eq!(cursor, meta.total_theta);
        // fisher segments: one per layer, sized cout, contiguous
        assert_eq!(meta.fisher_segments.len(), meta.scaled.layers.len());
        let mut fcur = 0;
        for (l, seg) in meta.fisher_segments.iter().enumerate() {
            assert_eq!(seg.layer, l);
            assert_eq!(seg.offset, fcur);
            assert_eq!(seg.size, meta.scaled.layers[l].cout);
            fcur += seg.size;
        }
        assert_eq!(fcur, meta.fisher_len);
        // episode shapes agree with the eval-batch convention
        let s = &meta.shapes;
        assert_eq!(s.eval_batch, s.max_support + s.max_query);
        assert_eq!(s.img, meta.scaled.img);
        // block conv ids point at in-range layers of that block
        for b in &meta.scaled.blocks {
            for &ci in &b.conv_ids {
                assert_eq!(meta.scaled.layers[ci].block, b.idx as i64);
            }
        }
    }
}
