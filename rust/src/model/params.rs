//! Mutable training state: flat theta + Adam moments + step counter.
//!
//! The coordinator owns exactly one of these per deployed model. He-init
//! and binary save/load live here; the packing comes from ModelMeta.
//!
//! The store itself (and the episode-facing `adapted_copy` /
//! `reset_optimizer` / `from_theta`) is `no_std + alloc`; He-init and
//! file I/O are std-only — an MCU deployment loads pretrained theta
//! bytes through [`ParamStore::from_theta`], it never He-inits.

#[cfg(feature = "std")]
use std::path::Path;

use alloc::{vec, vec::Vec};

#[cfg(feature = "std")]
use anyhow::{anyhow, Result};

use super::meta::ModelMeta;
#[cfg(feature = "std")]
use crate::util::rng::Rng;

/// Flat parameter store matching the AOT graphs' theta packing.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

#[cfg(feature = "std")]
const MAGIC: u32 = 0x7A11_0001; // "tinytrain weights v1"

impl ParamStore {
    /// Wrap an already-materialised theta (e.g. pretrained weights baked
    /// into MCU flash) with fresh optimiser state. The `no_std` analogue
    /// of `load`: length checking is on the caller, exactly as `load`
    /// checks against `meta.total_theta`.
    pub fn from_theta(meta: &ModelMeta, theta: Vec<f32>) -> ParamStore {
        debug_assert_eq!(theta.len(), meta.total_theta, "theta length mismatch");
        let n = theta.len();
        ParamStore { theta, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// He(-fan-in) initialisation: weights ~ N(0, sqrt(2/fan_in)),
    /// gamma = 1, beta = 0, adapters = 0 (inactive lite-residuals).
    #[cfg(feature = "std")]
    pub fn init(meta: &ModelMeta, seed: u64) -> ParamStore {
        let mut theta = vec![0.0f32; meta.total_theta];
        let mut rng = Rng::new(seed);
        for e in &meta.entries {
            match e.role.as_str() {
                "weight" => {
                    let fan_in: usize = if e.shape.len() > 1 {
                        e.shape[..e.shape.len() - 1].iter().product()
                    } else {
                        e.shape[0]
                    };
                    let std = (2.0 / fan_in.max(1) as f64).sqrt();
                    for x in &mut theta[e.offset..e.offset + e.size] {
                        *x = rng.normal_scaled(0.0, std) as f32;
                    }
                }
                "gamma" => theta[e.offset..e.offset + e.size].fill(1.0),
                // beta / adapter_w / adapter_b stay zero.
                _ => {}
            }
        }
        ParamStore { theta, m: vec![0.0; meta.total_theta], v: vec![0.0; meta.total_theta], t: 0 }
    }

    /// Fresh optimiser state (new task adaptation starts clean).
    pub fn reset_optimizer(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.t = 0;
    }

    /// The per-episode working copy a backend starts from: cloned theta,
    /// zeroed optimiser moments (adaptation always begins with a fresh
    /// optimiser — cheaper than clone + `reset_optimizer`, which copies
    /// the moments only to overwrite them).
    pub fn adapted_copy(&self) -> ParamStore {
        let n = self.theta.len();
        ParamStore { theta: self.theta.clone(), m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// Save theta to a little-endian binary file (moments are transient).
    #[cfg(feature = "std")]
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut bytes = Vec::with_capacity(8 + self.theta.len() * 4);
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&(self.theta.len() as u32).to_le_bytes());
        for v in &self.theta {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes).map_err(|e| anyhow!("writing {}: {e}", path.display()))
    }

    /// Load theta saved by `save`; moments start at zero.
    #[cfg(feature = "std")]
    pub fn load(meta: &ModelMeta, path: &Path) -> Result<ParamStore> {
        let bytes =
            std::fs::read(path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        if bytes.len() < 8 {
            return Err(anyhow!("{}: truncated weights file", path.display()));
        }
        let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(anyhow!("{}: bad magic {magic:#x}", path.display()));
        }
        let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        if n != meta.total_theta {
            return Err(anyhow!(
                "{}: has {n} params but {} expects {} — stale artifacts?",
                path.display(),
                meta.arch,
                meta.total_theta
            ));
        }
        if bytes.len() != 8 + 4 * n {
            return Err(anyhow!("{}: truncated payload", path.display()));
        }
        let theta: Vec<f32> = bytes[8..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(ParamStore { theta, m: vec![0.0; n], v: vec![0.0; n], t: 0 })
    }

    /// Load pre-trained weights if present, else He-init (and warn).
    #[cfg(feature = "std")]
    pub fn load_or_init(meta: &ModelMeta, path: &Path, seed: u64) -> ParamStore {
        match Self::load(meta, path) {
            Ok(p) => p,
            Err(_) => Self::init(meta, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::{
        ArchFlavor, EpisodeShapes, ModelMeta, ParamEntry,
    };

    fn tiny_meta() -> ModelMeta {
        // A hand-rolled two-entry meta for unit tests (no artifacts dep).
        ModelMeta {
            arch: "tiny".into(),
            scaled: empty_flavor(),
            paper: empty_flavor(),
            entries: vec![
                ParamEntry {
                    name: "l0.w".into(),
                    shape: vec![4, 3],
                    offset: 0,
                    size: 12,
                    role: "weight".into(),
                    layer: 0,
                    mask_axis: 1,
                },
                ParamEntry {
                    name: "l0.gamma".into(),
                    shape: vec![3],
                    offset: 12,
                    size: 3,
                    role: "gamma".into(),
                    layer: 0,
                    mask_axis: 0,
                },
                ParamEntry {
                    name: "l0.beta".into(),
                    shape: vec![3],
                    offset: 15,
                    size: 3,
                    role: "beta".into(),
                    layer: 0,
                    mask_axis: 0,
                },
            ],
            total_theta: 18,
            fisher_len: 3,
            fisher_segments: vec![],
            shapes: EpisodeShapes {
                img: 8,
                channels: 3,
                max_ways: 2,
                max_support: 4,
                max_query: 4,
                eval_batch: 8,
                feat_dim: 4,
                cosine_tau: 10.0,
            },
        }
    }

    fn empty_flavor() -> ArchFlavor {
        ArchFlavor {
            img: 8,
            feat_dim: 4,
            layers: vec![],
            blocks: vec![],
            total_params: 18,
            total_macs: 0,
        }
    }

    #[test]
    fn init_roles() {
        let meta = tiny_meta();
        let p = ParamStore::init(&meta, 1);
        assert_eq!(p.theta.len(), 18);
        // gamma == 1, beta == 0
        assert!(p.theta[12..15].iter().all(|&x| x == 1.0));
        assert!(p.theta[15..18].iter().all(|&x| x == 0.0));
        // weights non-degenerate
        let wsum: f32 = p.theta[..12].iter().map(|x| x.abs()).sum();
        assert!(wsum > 0.0);
    }

    #[test]
    fn save_load_roundtrip() {
        let meta = tiny_meta();
        let p = ParamStore::init(&meta, 7);
        let dir = std::env::temp_dir().join("tinytrain_test_weights.bin");
        p.save(&dir).unwrap();
        let q = ParamStore::load(&meta, &dir).unwrap();
        assert_eq!(p.theta, q.theta);
        assert_eq!(q.t, 0);
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn load_rejects_wrong_size() {
        let meta = tiny_meta();
        let p = ParamStore::init(&meta, 7);
        let path = std::env::temp_dir().join("tinytrain_test_weights_bad.bin");
        p.save(&path).unwrap();
        let mut meta2 = tiny_meta();
        meta2.total_theta = 99;
        assert!(ParamStore::load(&meta2, &path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
