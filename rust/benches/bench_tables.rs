//! `cargo bench` — end-to-end benchmarks, one group per paper artefact
//! family (in-house harness; criterion is not in the offline vendor set).
//!
//! - analytic: Table 2/7/8/11 accounting engine over paper-scale archs
//! - devices:  Table 9/10 / Figure 5 latency+energy simulation
//! - sampler:  Table 5 episode generation across all nine domains
//! - selection: Algorithm-1 scoring + budgeted selection + mask build

use std::time::Duration;

use tinytrain::accounting::{backward_macs, backward_memory, Optimizer, UpdatePlan};
use tinytrain::coordinator::selection::run_selection;
use tinytrain::coordinator::{Budgets, ChannelScheme, Criterion, FisherReport, ModelEngine};
use tinytrain::data::{all_domains, Sampler};
use tinytrain::devices::{pi_zero_2, train_cost};
use tinytrain::model::ParamStore;
use tinytrain::runtime::{ArtifactStore, Runtime};
use tinytrain::util::bench::bench;
use tinytrain::util::rng::Rng;

fn main() {
    let budget = Duration::from_millis(400);
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("bench_tables: PJRT runtime unavailable (stub xla backend) — skipping");
        return;
    };
    let store = ArtifactStore::discover(None).expect("run `make artifacts`");
    let engine = ModelEngine::load(&rt, &store, "mcunet").expect("engine");
    let meta = &engine.meta;
    let arch = &meta.paper;
    let (n, nb) = (arch.layers.len(), arch.blocks.len());

    println!("-- accounting engine (Tables 2/7/8) --");
    let plans = [
        UpdatePlan::full(n, nb),
        UpdatePlan::last_layer(n, nb),
        UpdatePlan::tinytl(n, nb),
    ];
    bench("table2: backward_memory x3 plans", budget, || {
        for p in &plans {
            std::hint::black_box(backward_memory(arch, p, Optimizer::Adam).total());
        }
    });
    bench("table2: backward_macs x3 plans", budget, || {
        for p in &plans {
            std::hint::black_box(backward_macs(arch, p).total());
        }
    });

    println!("-- device simulator (Tables 9/10, Figure 5) --");
    let dev = pi_zero_2();
    bench("fig5: train_cost full sweep", budget, || {
        for p in &plans {
            std::hint::black_box(train_cost(&dev, arch, p, 25, 40, true).total_s());
        }
    });

    println!("-- episode sampler (Table 5) --");
    let shapes = meta.shapes.clone();
    let domains = all_domains();
    bench("table5: one episode per domain (9 renders)", budget, || {
        let mut rng = Rng::new(3);
        for d in &domains {
            let s = Sampler::new(d.as_ref(), &shapes);
            std::hint::black_box(s.sample(&mut rng).support.len());
        }
    });

    println!("-- Algorithm 1 selection (Table 3 / Figures 4,6b) --");
    let params = ParamStore::init(meta, 1);
    let fisher = FisherReport {
        deltas: meta.scaled.layers.iter().map(|l| vec![0.5; l.cout]).collect(),
        potentials: meta.scaled.layers.iter().map(|l| l.cout as f64).collect(),
    };
    bench("selection: score+select+mask (multi-objective)", budget, || {
        let sel = run_selection(
            meta,
            Criterion::MultiObjective,
            Some(&fisher),
            &params.theta,
            Budgets::default(),
            0.5,
            ChannelScheme::Fisher,
            Optimizer::Adam,
        );
        std::hint::black_box(sel.mask(meta).nnz());
    });
    bench("selection: L2-norm criterion (no fisher)", budget, || {
        let sel = run_selection(
            meta,
            Criterion::L2Norm,
            None,
            &params.theta,
            Budgets::default(),
            0.5,
            ChannelScheme::L2Norm,
            Optimizer::Adam,
        );
        std::hint::black_box(sel.layers.len());
    });
}
