//! `cargo bench --bench bench_hotpath [-- smoke]` — hot-path benchmarks.
//!
//! Two sections:
//!
//! 1. **Pure-rust hot path** (always runs, stub backend included):
//!    before/after microbenches of the selection overhaul (greedy layer
//!    selection, evolutionary-search feasibility, mask
//!    build/materialise, the analytic masked step, the parallel episode
//!    grid) and of the episode-pipeline overhaul (`episode_pipeline`:
//!    cached renders + pooled tensors vs re-render + fresh allocations;
//!    `incremental_embed`: masked-delta re-embedding vs the seed's dense
//!    per-pixel re-embed; `kernels_accumulate`/`kernels_step_plan`:
//!    8-wide blocked accumulation and the per-mask compiled step plan
//!    vs their scalar reference arms) — on the synthetic architecture.
//!    The "before"
//!    arms re-implement the seed's full-recompute/dense logic verbatim,
//!    and each pair is asserted equivalent (bit-identical where the op
//!    is order-preserving, tight numeric tolerance for the delta-summed
//!    embeddings) before being timed. The `serve` section replays a
//!    multi-tenant (tenants × domains × episodes) trace through the
//!    adaptation service against its sequential-per-tenant reference
//!    arm — asserted bit-identical (episode results *and* final tenant
//!    deltas) before the arms are timed. Numbers land in
//!    `BENCH_hotpath.json` at the repo root (the perf trajectory
//!    artefact cited by README/ROADMAP).
//!
//! 2. **PJRT hot path** (skips on the vendored stub): the compiled
//!    embed / fisher / train-step executables, as before.
//!
//! `-- smoke` shrinks the timing budgets for CI.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use tinytrain::accounting::{backward_macs, backward_memory, CostLedger, Optimizer, UpdatePlan};
use tinytrain::coordinator::backend::{AdaptationBackend, AnalyticBackend};
use tinytrain::coordinator::selection::select_layers;
use tinytrain::coordinator::{
    episode_accuracy, Budgets, Method, ModelEngine, Selection, SyncedParams, UpdateMask,
};
use tinytrain::data::{
    augment, domain_by_name, Episode, PaddedEpisode, RenderCache, Sample, Sampler,
};
use tinytrain::harness::parallel::{accuracy_grid, cell_seed, episode_streams, GridConfig};
use tinytrain::model::{EpisodeShapes, ModelMeta, ParamStore};
use tinytrain::net::proto;
use tinytrain::runtime::{ArtifactStore, Runtime};
use tinytrain::serve::{
    self, shard::auto_shards, LoopMode, QuantPolicy, Residency, ServeConfig, TenantStore,
    TenantStoreConfig, TraceConfig,
};
use tinytrain::util::bench::bench;
use tinytrain::util::jsonio::{num, obj, s, Json};
use tinytrain::util::pool::default_workers;
use tinytrain::util::rng::Rng;

/// The seed's greedy selection: full `backward_memory`/`backward_macs`
/// recomputation per candidate layer (the O(n²) "before" arm).
fn reference_select_layers(
    meta: &ModelMeta,
    scores: &[f64],
    budgets: Budgets,
    ratio: f64,
) -> Vec<usize> {
    let budgets = budgets.resolve(meta);
    let arch = &meta.scaled;
    let n = arch.layers.len();
    let full_bwd = {
        let mut p = UpdatePlan::full(n, arch.blocks.len());
        p.batch = 1;
        backward_macs(arch, &p).total()
    };
    let compute_budget = full_bwd * budgets.compute_frac;
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut plan = UpdatePlan::frozen(n, arch.blocks.len());
    let mut selected = Vec::new();
    for &l in &order {
        plan.layer_ratio[l] = ratio;
        let mem = backward_memory(arch, &plan, Optimizer::Adam).total();
        let macs = backward_macs(arch, &plan).total();
        if mem <= budgets.mem_bytes && macs <= compute_budget {
            selected.push(l);
        } else {
            plan.layer_ratio[l] = 0.0;
        }
    }
    selected
}

/// The seed's per-genome feasibility: plan build + full memory recompute.
fn reference_feasible(meta: &ModelMeta, genome: &[usize], budget: f64) -> bool {
    const RATIO_CHOICES: [f64; 5] = [0.0, 0.125, 0.25, 0.5, 1.0];
    let arch = &meta.scaled;
    let mut plan = UpdatePlan::frozen(arch.layers.len(), arch.blocks.len());
    for (l, &r) in genome.iter().enumerate() {
        plan.layer_ratio[l] = RATIO_CHOICES[r];
    }
    backward_memory(arch, &plan, Optimizer::Adam).total() <= budget
}

/// The seed's dense selection-mask build (modular channel rule over a
/// freshly allocated theta-length vector).
fn reference_selection_mask(meta: &ModelMeta, sel: &Selection) -> Vec<f32> {
    let mut mask = vec![0.0f32; meta.total_theta];
    for (i, &l) in sel.layers.iter().enumerate() {
        let mut on = vec![false; meta.scaled.layers[l].cout];
        for &c in &sel.channels[i] {
            on[c] = true;
        }
        for e in meta.layer_entries(l) {
            let cout = *e.shape.last().unwrap();
            let seg = &mut mask[e.offset..e.offset + e.size];
            for (j, v) in seg.iter_mut().enumerate() {
                if on[j % cout] {
                    *v = 1.0;
                }
            }
        }
    }
    mask
}

/// The seed's `Episode::pad`: a fresh zeroed `Vec` per tensor.
#[allow(clippy::type_complexity)]
fn reference_pad(
    ep: &Episode,
    s: &EpisodeShapes,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let img_len = s.img * s.img * s.channels;
    let pack = |samples: &[Sample], cap: usize| {
        let mut x = vec![0.0f32; cap * img_len];
        let mut y = vec![0.0f32; cap * s.max_ways];
        let mut v = vec![0.0f32; cap];
        for (i, smp) in samples.iter().take(cap).enumerate() {
            x[i * img_len..(i + 1) * img_len].copy_from_slice(&smp.image);
            y[i * s.max_ways + smp.label] = 1.0;
            v[i] = 1.0;
        }
        (x, y, v)
    };
    let (sx, sy, sv) = pack(&ep.support, s.max_support);
    let (qx, qy, qv) = pack(&ep.query, s.max_query);
    (sx, sy, sv, qx, qy, qv)
}

/// The seed's `Episode::pseudo_query`: fresh vecs plus one augment
/// allocation per pseudo row.
fn reference_pseudo(
    ep: &Episode,
    s: &EpisodeShapes,
    rng: &mut Rng,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let img_len = s.img * s.img * s.channels;
    let cap = s.max_query;
    let mut x = vec![0.0f32; cap * img_len];
    let mut y = vec![0.0f32; cap * s.max_ways];
    let mut v = vec![0.0f32; cap];
    if ep.support.is_empty() {
        return (x, y, v);
    }
    for i in 0..cap {
        let src = &ep.support[rng.below(ep.support.len())];
        let aug = augment(&src.image, s.img, s.channels, rng);
        x[i * img_len..(i + 1) * img_len].copy_from_slice(&aug);
        y[i * s.max_ways + src.label] = 1.0;
        v[i] = 1.0;
    }
    (x, y, v)
}

/// The seed's analytic embedding: per-pixel hash into theta, a fresh
/// row buffer per image, full recompute per call.
fn reference_embed(meta: &ModelMeta, theta: &[f32], padded: &PaddedEpisode) -> Vec<f32> {
    let s = &meta.shapes;
    let img_len = s.img * s.img * s.channels;
    let proj_weight = |i: usize| -> f32 {
        if theta.is_empty() {
            return 1.0;
        }
        let h = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17);
        theta[(h % theta.len() as u64) as usize] + 0.05
    };
    let mut out = Vec::with_capacity(s.eval_batch * s.feat_dim);
    let mut embed_images = |images: &[f32], out: &mut Vec<f32>| {
        let n = images.len() / img_len.max(1);
        for b in 0..n {
            let img = &images[b * img_len..(b + 1) * img_len];
            let mut row = vec![0.0f32; s.feat_dim];
            for (i, &x) in img.iter().enumerate() {
                row[i % s.feat_dim] += x * proj_weight(i);
            }
            let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
            for v in &mut row {
                *v /= norm;
            }
            out.extend_from_slice(&row);
        }
    };
    embed_images(&padded.sup_x, &mut out);
    embed_images(&padded.qry_x, &mut out);
    out
}

/// The analytic masked step applied to a dense theta (reference arm).
fn step_dense(theta: &mut [f32], runs: &[(usize, usize)], lr: f32) {
    for &(off, len) in runs {
        for p in &mut theta[off..off + len] {
            *p -= lr * 0.1 * *p;
        }
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn speedup_entry(name: &str, before_s: f64, after_s: f64) -> (String, Json) {
    let section = obj(vec![
        ("before_us", num(before_s * 1e6)),
        ("after_us", num(after_s * 1e6)),
        ("speedup", num(before_s / after_s.max(1e-12))),
    ]);
    (name.to_string(), section)
}

fn pure_rust_section(smoke: bool) -> Vec<(String, Json)> {
    let budget = Duration::from_millis(if smoke { 40 } else { 400 });
    let meta = ModelMeta::synthetic(12);
    let n = meta.scaled.layers.len();
    println!("-- pure-rust hot path (synthetic arch: {} layers, theta={}) --", n, meta.total_theta);
    let mut sections: Vec<(String, Json)> = vec![
        ("arch".into(), s(&meta.arch)),
        ("layers".into(), num(n as f64)),
        ("total_theta".into(), num(meta.total_theta as f64)),
    ];

    // --- greedy layer selection -----------------------------------------
    let mut rng = Rng::new(11);
    let scores: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
    let budgets = Budgets::default();
    assert_eq!(
        reference_select_layers(&meta, &scores, budgets, 0.5),
        select_layers(&meta, &scores, budgets, 0.5, Optimizer::Adam),
        "ledger selection diverged from the full-recompute reference"
    );
    let before = bench("select_layers: full recompute (before)", budget, || {
        std::hint::black_box(reference_select_layers(&meta, &scores, budgets, 0.5).len());
    });
    let after = bench("select_layers: CostLedger (after)", budget, || {
        std::hint::black_box(select_layers(&meta, &scores, budgets, 0.5, Optimizer::Adam).len());
    });
    sections.push(speedup_entry("select_layers", before.mean_secs(), after.mean_secs()));

    // --- evolutionary-search feasibility --------------------------------
    let genomes: Vec<Vec<usize>> = (0..64)
        .map(|_| (0..n).map(|_| if rng.bool(0.75) { 0 } else { 1 + rng.below(4) }).collect())
        .collect();
    let search_budget = {
        let auto = budgets.resolve(&meta);
        let peak = tinytrain::accounting::activation_peak_bytes(&meta.scaled);
        peak + 1.6 * (auto.mem_bytes - peak)
    };
    fn ledger_feasible(ledger: &mut CostLedger<'_>, g: &[usize], budget: f64) -> bool {
        const RATIO_CHOICES: [f64; 5] = [0.0, 0.125, 0.25, 0.5, 1.0];
        for (l, &r) in g.iter().enumerate() {
            if r > 0 {
                ledger.set_ratio(l, RATIO_CHOICES[r]);
            }
        }
        let ok = ledger.memory_total() <= budget;
        for (l, &r) in g.iter().enumerate() {
            if r > 0 {
                ledger.set_ratio(l, 0.0);
            }
        }
        ok
    }
    let mut ledger = CostLedger::new(&meta.scaled, Optimizer::Adam);
    for g in &genomes {
        assert_eq!(
            reference_feasible(&meta, g, search_budget),
            ledger_feasible(&mut ledger, g, search_budget),
            "ledger feasibility diverged on {g:?}"
        );
    }
    let before = bench("search feasibility: full recompute x64 (before)", budget, || {
        let ok = genomes.iter().filter(|g| reference_feasible(&meta, g, search_budget)).count();
        std::hint::black_box(ok);
    });
    let after = bench("search feasibility: CostLedger deltas x64 (after)", budget, || {
        let ok = genomes
            .iter()
            .filter(|g| ledger_feasible(&mut ledger, g, search_budget))
            .count();
        std::hint::black_box(ok);
    });
    sections.push(speedup_entry("search_feasibility", before.mean_secs(), after.mean_secs()));

    // --- selection mask: build + materialise ----------------------------
    // Deepest third of the layers at every-other channel — the striding
    // worst case for the run representation.
    let sel = {
        let layers: Vec<usize> = (2 * n / 3..n).collect();
        let channels: Vec<Vec<usize>> = layers
            .iter()
            .map(|&l| (0..meta.scaled.layers[l].cout).step_by(2).collect())
            .collect();
        Selection { layers, channels, ratio: 0.5, scores: vec![] }
    };
    assert_eq!(
        reference_selection_mask(&meta, &sel),
        sel.mask(&meta).dense(),
        "segment mask diverged from the dense reference"
    );
    let before = bench("selection mask: dense build (before)", budget, || {
        std::hint::black_box(reference_selection_mask(&meta, &sel).len());
    });
    let after = bench("selection mask: segment build (after)", budget, || {
        std::hint::black_box(sel.mask(&meta).nnz());
    });
    sections.push(speedup_entry("mask_build", before.mean_secs(), after.mean_secs()));
    let mask = sel.mask(&meta);
    let materialise = bench("selection mask: one-time dense materialise", budget, || {
        std::hint::black_box(mask.dense().len());
    });
    sections.push(("mask_materialise_us".into(), num(materialise.mean_secs() * 1e6)));

    // --- analytic masked step: dense scan vs segment runs ---------------
    let params = ParamStore::init(&meta, 1);
    let domain = domain_by_name("traffic").unwrap();
    let mut erng = Rng::new(5);
    let ep = Sampler::new(domain.as_ref(), &meta.shapes).sample(&mut erng);
    let padded = ep.pad(&meta.shapes);
    let pseudo = ep.pseudo_query(&meta.shapes, &mut erng);
    let dense = mask.dense();
    let mut theta = params.theta.clone();
    let before = bench("analytic step: dense mask scan (before)", budget, || {
        for (p, &m) in theta.iter_mut().zip(dense.iter()) {
            if m > 0.0 {
                *p -= 1e-3 * m * 0.1 * *p;
            }
        }
        std::hint::black_box(theta[0]);
    });
    let mut backend = AnalyticBackend::new(&meta, &params, padded.clone(), pseudo.clone());
    backend.set_mask(&mask).unwrap();
    let after = bench("analytic step: segment runs (after)", budget, || {
        std::hint::black_box(backend.step(1e-3).unwrap());
    });
    sections.push(speedup_entry("analytic_step", before.mean_secs(), after.mean_secs()));

    // --- pure-rust episode evaluator (unchanged baseline, kept for the
    //     trajectory) -----------------------------------------------------
    let emb = backend.embed().unwrap();
    let eval = bench("evaluator: prototypes + cosine top-1", budget, || {
        std::hint::black_box(episode_accuracy(&emb, &padded, &meta.shapes));
    });
    sections.push(("episode_eval_us".into(), num(eval.mean_secs() * 1e6)));

    // --- episode pipeline: cached renders + pooled tensors ---------------
    // Before: the seed's data path — rasterize every image, allocate
    // fresh zeroed tensors for pad/pseudo. After: the same streams
    // through the render cache and the thread-local scratch arena.
    // Replaying fixed streams is exactly what the grid does (every
    // method re-runs the same per-cell episode streams).
    let streams = episode_streams(cell_seed(7, "traffic"), 4);
    let pipeline_cache = RenderCache::new(4, 4096);
    let uncached = Sampler::new(domain.as_ref(), &meta.shapes).with_cache(None);
    let cached = Sampler::new(domain.as_ref(), &meta.shapes).with_cache(Some(&pipeline_cache));
    for stream in &streams {
        let mut r_a = stream.clone();
        let ep_a = uncached.sample(&mut r_a);
        let (sx, sy, sv, qx, qy, qv) = reference_pad(&ep_a, &meta.shapes);
        let (px, py, pv) = reference_pseudo(&ep_a, &meta.shapes, &mut r_a);
        let mut r_b = stream.clone();
        let ep_b = cached.sample(&mut r_b);
        let p = ep_b.pad(&meta.shapes);
        let q = ep_b.pseudo_query(&meta.shapes, &mut r_b);
        assert_eq!(r_a.state(), r_b.state(), "cache shifted the episode stream");
        assert!(
            p.sup_x[..] == sx[..] && p.sup_y[..] == sy[..] && p.sup_v[..] == sv[..],
            "pooled pad diverged from the dense reference (support)"
        );
        assert!(
            p.qry_x[..] == qx[..] && p.qry_y[..] == qy[..] && p.qry_v[..] == qv[..],
            "pooled pad diverged from the dense reference (query)"
        );
        assert!(
            q.x[..] == px[..] && q.y[..] == py[..] && q.v[..] == pv[..],
            "pooled pseudo-query diverged from the dense reference"
        );
    }
    let before = bench("episode pipeline: re-render + fresh tensors (before)", budget, || {
        for stream in &streams {
            let mut r = stream.clone();
            let ep = uncached.sample(&mut r);
            let p = reference_pad(&ep, &meta.shapes);
            let q = reference_pseudo(&ep, &meta.shapes, &mut r);
            std::hint::black_box((p.0.len(), q.0.len()));
        }
    });
    let after = bench("episode pipeline: render cache + arenas (after)", budget, || {
        for stream in &streams {
            let mut r = stream.clone();
            let ep = cached.sample(&mut r);
            let p = ep.pad(&meta.shapes);
            let q = ep.pseudo_query(&meta.shapes, &mut r);
            std::hint::black_box((p.sup_x.len(), q.x.len()));
        }
    });
    sections.push(speedup_entry("episode_pipeline", before.mean_secs(), after.mean_secs()));

    // --- incremental masked re-embedding ---------------------------------
    // Before: masked step + the seed's dense per-pixel re-embed. After:
    // masked step whose deltas land directly in the cached pre-norm
    // rows, plus a normalise-only embed. Mask: the head layer (the
    // LastLayer shape — small against theta, the regime the scatter
    // table targets).
    let head_mask = {
        let mut b = UpdateMask::builder(meta.total_theta);
        for e in meta.layer_entries(meta.head_layer()) {
            b.add_entry(e.offset, e.size);
        }
        b.build().unwrap()
    };
    let mut ref_theta = params.theta.clone();
    let mut inc = AnalyticBackend::new(&meta, &params, padded.clone(), pseudo.clone());
    // pre-adaptation eval builds the embed state, as in the session flow
    let pre = inc.embed().unwrap();
    assert!(pre[..] == reference_embed(&meta, &ref_theta, &padded)[..], "pre-step embed diverged");
    inc.set_mask(&head_mask).unwrap();
    let (affected, incremental) = inc.embed_plan().unwrap();
    assert!(incremental, "head mask must take the incremental path (affected={affected})");
    let lr = 1e-3f32;
    for step in 0..6 {
        inc.step(lr).unwrap();
        step_dense(&mut ref_theta, head_mask.runs(), lr);
        let fast = inc.embed().unwrap();
        let slow = reference_embed(&meta, &ref_theta, &padded);
        let max_diff = max_abs_diff(&fast, &slow);
        assert!(
            max_diff < 1e-4,
            "incremental embed diverged from dense recompute at step {step}: {max_diff}"
        );
        assert_eq!(
            episode_accuracy(&fast, &padded, &meta.shapes),
            episode_accuracy(&slow, &padded, &meta.shapes),
            "incremental embed changed episode accuracy at step {step}"
        );
    }
    let before = bench("masked step + dense re-embed (before)", budget, || {
        step_dense(&mut ref_theta, head_mask.runs(), lr);
        std::hint::black_box(reference_embed(&meta, &ref_theta, &padded).len());
    });
    let after = bench("masked step + incremental re-embed (after)", budget, || {
        inc.step(lr).unwrap();
        std::hint::black_box(inc.embed().unwrap().len());
    });
    sections.push(speedup_entry("incremental_embed", before.mean_secs(), after.mean_secs()));

    // --- kernels: blocked accumulate + compiled step plan ----------------
    // The "before" arms are the scalar implementations kept in
    // `coordinator::analytic` as references: blocked accumulation
    // preserves per-lane addition order, and the compiled StepPlan
    // replays the exact slot/value visit sequence of the scalar bucket
    // walk, so both pairs are bit-identical — asserted here before any
    // timing, and property-tested in tests/{hotpath,no_std_core}.rs.
    {
        use tinytrain::coordinator::analytic::{
            accumulate_rows, masked_shrink_step, masked_shrink_step_scalar, EmbedState,
        };
        let s = &meta.shapes;
        let img_len = s.img * s.img * s.channels;
        let sup_rows = s.max_support * s.feat_dim;
        let st = EmbedState::build(
            s,
            meta.total_theta,
            |t| params.theta[t],
            &padded.sup_x,
            &padded.qry_x,
        );
        // blocked-vs-scalar accumulate (the dense rebuild both arms run)
        let proj: Vec<f32> = st.proj.to_vec();
        let embed_plan = st.plan;
        let mut raw_ref = vec![0.0f32; s.eval_batch * s.feat_dim];
        accumulate_rows(&padded.sup_x, img_len, &proj, s.feat_dim, &mut raw_ref[..sup_rows]);
        accumulate_rows(&padded.qry_x, img_len, &proj, s.feat_dim, &mut raw_ref[sup_rows..]);
        assert!(
            raw_ref.iter().zip(st.raw.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "blocked accumulate is not bit-identical to the scalar arm"
        );
        let before = bench("kernels: scalar accumulate_rows (before)", budget, || {
            raw_ref.fill(0.0);
            accumulate_rows(&padded.sup_x, img_len, &proj, s.feat_dim, &mut raw_ref[..sup_rows]);
            accumulate_rows(&padded.qry_x, img_len, &proj, s.feat_dim, &mut raw_ref[sup_rows..]);
            std::hint::black_box(raw_ref[0]);
        });
        let mut raw_blk = vec![0.0f32; s.eval_batch * s.feat_dim];
        let after = bench("kernels: 8-wide blocked accumulate (after)", budget, || {
            raw_blk.fill(0.0);
            embed_plan.accumulate(&padded.sup_x, &proj, &mut raw_blk[..sup_rows]);
            embed_plan.accumulate(&padded.qry_x, &proj, &mut raw_blk[sup_rows..]);
            std::hint::black_box(raw_blk[0]);
        });
        sections.push(speedup_entry("kernels_accumulate", before.mean_secs(), after.mean_secs()));

        // plan-vs-unplanned masked step over the same head mask the
        // incremental_embed section adapts with
        let overlay_init: Vec<Vec<f32>> = head_mask
            .runs()
            .iter()
            .map(|&(off, len)| params.theta[off..off + len].to_vec())
            .collect();
        let build_state = || {
            let mut st = EmbedState::build(
                s,
                meta.total_theta,
                |t| params.theta[t],
                &padded.sup_x,
                &padded.qry_x,
            );
            st.refresh_plan(Some(&head_mask), &padded.sup_x, &padded.qry_x);
            st
        };
        let mut st_plan = build_state();
        let mut st_scalar = build_state();
        assert!(st_plan.incremental, "head mask must compile an incremental plan");
        let mut ov_plan = overlay_init.clone();
        let mut ov_scalar = overlay_init;
        for _ in 0..4 {
            masked_shrink_step(
                &head_mask,
                &mut ov_plan,
                Some(&mut st_plan),
                s,
                &padded.sup_x,
                &padded.qry_x,
                lr,
            );
            masked_shrink_step_scalar(
                &head_mask,
                &mut ov_scalar,
                Some(&mut st_scalar),
                s,
                &padded.sup_x,
                &padded.qry_x,
                lr,
            );
        }
        assert!(
            st_plan.raw.iter().zip(st_scalar.raw.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "compiled step plan raw is not bit-identical to the scalar bucket walk"
        );
        assert!(
            st_plan.proj.iter().zip(st_scalar.proj.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "compiled step plan proj is not bit-identical to the scalar bucket walk"
        );
        let before = bench("kernels: scalar masked step (before)", budget, || {
            masked_shrink_step_scalar(
                &head_mask,
                &mut ov_scalar,
                Some(&mut st_scalar),
                s,
                &padded.sup_x,
                &padded.qry_x,
                lr,
            );
            std::hint::black_box(ov_scalar[0][0]);
        });
        let after = bench("kernels: compiled-plan masked step (after)", budget, || {
            masked_shrink_step(
                &head_mask,
                &mut ov_plan,
                Some(&mut st_plan),
                s,
                &padded.sup_x,
                &padded.qry_x,
                lr,
            );
            std::hint::black_box(ov_plan[0][0]);
        });
        sections.push(speedup_entry("kernels_step_plan", before.mean_secs(), after.mean_secs()));
    }

    // --- parallel episode grid ------------------------------------------
    let episodes = if smoke { 2 } else { 6 };
    let methods = vec![Method::LastLayer, Method::tinytrain_default()];
    let domains: Vec<String> = ["traffic", "cub"].iter().map(|d| d.to_string()).collect();
    let serial_cfg =
        GridConfig { episodes, steps: 6, lr: 6e-3, seed: 7, workers: 1, render_cache: true };
    let workers = default_workers();
    let par_cfg = GridConfig { workers, ..serial_cfg.clone() };
    let t0 = std::time::Instant::now();
    let serial = accuracy_grid(&meta, &params, &methods, &domains, &serial_cfg).unwrap();
    let serial_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let parallel = accuracy_grid(&meta, &params, &methods, &domains, &par_cfg).unwrap();
    let parallel_s = t0.elapsed().as_secs_f64();
    for (srow, prow) in serial.iter().zip(&parallel) {
        for (sc, pc) in srow.iter().zip(prow) {
            assert_eq!(sc.mean_acc, pc.mean_acc, "parallel grid diverged from serial");
        }
    }
    println!(
        "episode grid: {} episodes serial {serial_s:.3}s | {workers} workers {parallel_s:.3}s",
        methods.len() * domains.len() * episodes
    );
    sections.push((
        "episode_grid".into(),
        obj(vec![
            ("episodes", num((methods.len() * domains.len() * episodes) as f64)),
            ("serial_s", num(serial_s)),
            ("workers", num(workers as f64)),
            ("parallel_s", num(parallel_s)),
            ("speedup", num(serial_s / parallel_s.max(1e-12))),
        ]),
    ));

    // --- multi-tenant serve: worker pool vs sequential reference --------
    // Same trace through both arms; the reference arm replays it in
    // strict order on one thread. An untimed pass first asserts the two
    // bit-identical (results *and* final per-tenant deltas) and warms
    // the shared render cache, so the timed arms see equal steady state.
    let trace_cfg = TraceConfig {
        tenants: 8,
        domains: ["traffic", "cub"].iter().map(|d| d.to_string()).collect(),
        episodes: if smoke { 2 } else { 4 },
        seed: 7,
        // Loose budgets so dynamic selection does real work on the
        // synthetic arch (AUTO targets mcunet-class layer tables).
        method: Method::TinyTrain {
            criterion: tinytrain::coordinator::Criterion::MultiObjective,
            scheme: tinytrain::coordinator::ChannelScheme::Fisher,
            budgets: Budgets { mem_bytes: 1e7, compute_frac: 1.0 },
            ratio: 0.5,
        },
        steps: 6,
        lr: 6e-3,
    };
    let trace = serve::synthetic_trace(&trace_cfg);
    let base = Arc::new(params.clone());
    let scfg = ServeConfig {
        workers: default_workers(),
        queue_capacity: 64,
        render_cache: true,
        faults: None,
        ..ServeConfig::default()
    };
    let unbounded = |base: &Arc<ParamStore>| {
        TenantStoreConfig { shards: 1, ..TenantStoreConfig::default() }
            .build(Arc::clone(base))
            .expect("unbounded single-shard store")
    };
    let check_seq = unbounded(&base);
    let check_ref = serve::sequential_replay(&meta, &check_seq, &trace, true);
    let check_par_store = unbounded(&base);
    let check_par = serve::replay(&meta, &check_par_store, &scfg, &trace, LoopMode::Open)
        .expect("serve replay");
    serve::check_equivalent(&check_ref.completions, &check_par.completions)
        .expect("serve arm diverged from the sequential reference");
    for t in 0..trace_cfg.tenants {
        let name = serve::tenant_name(t);
        assert_eq!(
            check_seq.delta(&name),
            check_par_store.delta(&name),
            "tenant {name}: final delta diverged from the reference arm"
        );
    }
    let seq_store = unbounded(&base);
    let seq = serve::sequential_replay(&meta, &seq_store, &trace, true);
    let par_store = unbounded(&base);
    let par = serve::replay(&meta, &par_store, &scfg, &trace, LoopMode::Open)
        .expect("serve replay");
    println!(
        "serve: {} requests ({} tenants) sequential {:.3}s | {} workers {:.3}s p95={:.0}us",
        trace.len(),
        trace_cfg.tenants,
        seq.wall_s,
        par.workers,
        par.wall_s,
        par.total.p95_us
    );
    sections.push((
        "serve".into(),
        obj(vec![
            ("requests", num(trace.len() as f64)),
            ("tenants", num(trace_cfg.tenants as f64)),
            ("workers", num(par.workers as f64)),
            ("before_us", num(seq.wall_s * 1e6)),
            ("after_us", num(par.wall_s * 1e6)),
            ("speedup", num(seq.wall_s / par.wall_s.max(1e-12))),
            ("throughput_rps", num(par.throughput_rps)),
            ("p95_us", num(par.total.p95_us)),
        ]),
    ));

    // --- tenant sweep: single-mutex vs sharded tenant plane -------------
    // Raw store traffic (absorb + params_for, no adaptation math), so
    // the arms time the store's locking. Both arms do identical
    // per-tenant work; the after arm hashes tenants across shards, and
    // an untimed pre-pass asserts the arms land bit-identical (shard
    // count is unobservable with quantization off and no budget).
    let sweep_tenants = if smoke { 16 } else { 64 };
    let sweep_workers = default_workers().clamp(2, 8);
    let sweep_rounds = if smoke { 8 } else { 32 };
    let sweep_weights = 64usize;
    let offset_span = meta.total_theta.saturating_sub(sweep_weights).max(1);
    let sweep = |store: &TenantStore| {
        let t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            for w in 0..sweep_workers {
                scope.spawn(move || {
                    for round in 0..sweep_rounds {
                        let mut t = w;
                        while t < sweep_tenants {
                            let name = serve::tenant_name(t);
                            let fill = (round * sweep_tenants + t) as f32 * 1e-3 + 1.0;
                            let segments = vec![(t * 97 % offset_span, vec![fill; sweep_weights])];
                            store.absorb(&name, SyncedParams::Sparse { t: 1, segments });
                            std::hint::black_box(store.params_for(&name).t);
                            t += sweep_workers;
                        }
                    }
                });
            }
        });
        t0.elapsed().as_secs_f64()
    };
    let single = unbounded(&base);
    let shards = auto_shards(sweep_workers);
    let sharded = TenantStoreConfig { shards, ..TenantStoreConfig::default() }
        .build(Arc::clone(&base))
        .expect("sharded store");
    sweep(&single); // untimed warm + correctness pass
    sweep(&sharded);
    for t in 0..sweep_tenants {
        let name = serve::tenant_name(t);
        assert_eq!(
            single.delta(&name),
            sharded.delta(&name),
            "tenant {name}: sharded sweep diverged from the single-mutex arm"
        );
    }
    let single_s = sweep(&single);
    let sharded_s = sweep(&sharded);
    println!(
        "tenant sweep: {sweep_tenants} tenants x {sweep_workers} workers single-mutex \
         {single_s:.3}s ({} contended) | {shards} shards {sharded_s:.3}s ({} contended)",
        single.stats().contended,
        sharded.stats().contended
    );

    // Residency at a fixed budget, with and without cold quantization:
    // int8 overlays cost ~1/4 of f32, so the same budget keeps more
    // tenants resident instead of spilling them.
    let sweep_budget = sweep_tenants as f64 / 4.0 * sweep_weights as f64 * 4.0;
    let spill_root = std::env::temp_dir().join(format!("tt-bench-sweep-{}", std::process::id()));
    let residency = |arm: &str, quantize: QuantPolicy| {
        let store = TenantStoreConfig {
            budget_bytes: sweep_budget,
            shards: 1,
            quantize,
            spill_dir: Some(spill_root.join(arm)),
            ..TenantStoreConfig::default()
        }
        .build(Arc::clone(&base))
        .expect("budgeted store");
        for t in 0..sweep_tenants {
            let segments = vec![(t * 97 % offset_span, vec![1.0f32; sweep_weights])];
            store.absorb(&serve::tenant_name(t), SyncedParams::Sparse { t: 1, segments });
        }
        let mut counts = [0usize; 3];
        for t in 0..sweep_tenants {
            match store.tenant_stats(&serve::tenant_name(t)).map(|s| s.residency) {
                Some(Residency::Resident) => counts[0] += 1,
                Some(Residency::Quantized) => counts[1] += 1,
                Some(Residency::Spilled) => counts[2] += 1,
                None => {}
            }
        }
        counts
    };
    let off = residency("off", QuantPolicy::Off);
    let cold = residency("cold", QuantPolicy::Cold { hot_fraction: 0.25 });
    std::fs::remove_dir_all(&spill_root).ok();
    println!(
        "tenant sweep residency @ {:.0} bytes: quantize off {}/{}/{} \
         (resident/quantized/spilled) | quantize 0.25 {}/{}/{}",
        sweep_budget, off[0], off[1], off[2], cold[0], cold[1], cold[2]
    );
    sections.push((
        "tenant_sweep".into(),
        obj(vec![
            ("tenants", num(sweep_tenants as f64)),
            ("workers", num(sweep_workers as f64)),
            ("shards", num(shards as f64)),
            ("before_us", num(single_s * 1e6)),
            ("after_us", num(sharded_s * 1e6)),
            ("speedup", num(single_s / sharded_s.max(1e-12))),
            ("contended_before", num(single.stats().contended as f64)),
            ("contended_after", num(sharded.stats().contended as f64)),
            ("resident_off", num(off[0] as f64)),
            ("quantized_off", num(off[1] as f64)),
            ("spilled_off", num(off[2] as f64)),
            ("resident_quant", num(cold[0] as f64)),
            ("quantized_quant", num(cold[1] as f64)),
            ("spilled_quant", num(cold[2] as f64)),
        ]),
    ));

    // --- wire decode: lazy byte scanner vs tree parser ------------------
    // The serve trace doubles as the request corpus: every request body
    // is decoded by both arms and asserted field-identical before the
    // arms are timed (ADR-002's no-tree claim, measured and checked).
    let bodies: Vec<String> = trace
        .iter()
        .map(|r| {
            proto::submit_body(&r.tenant, &r.domain, "tinytrain", r.steps, r.lr, r.stream.state())
        })
        .collect();
    for body in &bodies {
        assert_eq!(
            proto::decode_submit_lazy(body.as_bytes()).expect("lazy decode"),
            proto::decode_submit_tree(body.as_bytes()).expect("tree decode"),
            "decode arms diverged on {body}"
        );
    }
    let before = bench("net decode: tree parser (before)", budget, || {
        for b in &bodies {
            std::hint::black_box(proto::decode_submit_tree(b.as_bytes()).unwrap().steps);
        }
    });
    let after = bench("net decode: lazy scanner (after)", budget, || {
        for b in &bodies {
            std::hint::black_box(proto::decode_submit_lazy(b.as_bytes()).unwrap().steps);
        }
    });
    sections.push(speedup_entry("net_decode", before.mean_secs(), after.mean_secs()));
    sections
}

fn write_report(smoke: bool, sections: Vec<(String, Json)>) {
    let fields: Vec<(&str, Json)> =
        sections.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let report = obj(vec![
        ("bench", s("hotpath")),
        ("mode", s(if smoke { "smoke" } else { "full" })),
        ("sections", obj(fields)),
    ]);
    // repo root: <manifest>/..
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_hotpath.json");
    match std::fs::write(&path, report.to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("bench_hotpath: could not write {}: {e}", path.display()),
    }
}

fn pjrt_section(budget: Duration) {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("bench_hotpath: PJRT runtime unavailable (stub xla backend) — section skipped");
        return;
    };
    let store = ArtifactStore::discover(None).expect("run `make artifacts`");
    let engine = ModelEngine::load(&rt, &store, "mcunet").expect("engine");
    let meta = &engine.meta;
    let mut params = ParamStore::init(meta, 1);

    let domain = domain_by_name("traffic").unwrap();
    let mut rng = Rng::new(5);
    let ep = Sampler::new(domain.as_ref(), &meta.shapes).sample(&mut rng);
    let padded = ep.pad(&meta.shapes);
    let pseudo = ep.pseudo_query(&meta.shapes, &mut rng);
    let mask = vec![1.0f32; meta.total_theta];

    println!(
        "-- PJRT hot path (mcunet scaled, EVAL_BATCH={}) --",
        meta.shapes.eval_batch
    );
    // warm-up: compile outside the timed regions
    engine.embed_with(&params, engine.eval_batch(&padded)).unwrap();
    engine.fisher_pass(&params, &padded, &pseudo).unwrap();
    engine
        .train_step(&mut params.clone(), &mask, 1e-3, &padded, &pseudo)
        .unwrap();

    bench("fwd: embed eval batch", budget, || {
        std::hint::black_box(
            engine.embed_with(&params, engine.eval_batch(&padded)).unwrap().data[0],
        );
    });
    bench("fisher pass (support+pseudo-query)", budget, || {
        std::hint::black_box(engine.fisher_pass(&params, &padded, &pseudo).unwrap().loss);
    });
    bench("train step (host round-trip path)", budget, || {
        std::hint::black_box(
            engine.train_step(&mut params, &mask, 1e-3, &padded, &pseudo).unwrap(),
        );
    });

    // Device-resident path (§Perf optimisation): theta/m/v stay on device.
    let mut state = engine.upload_state(&params).unwrap();
    let dev_ep = engine.upload_episode(&padded, &pseudo).unwrap();
    let mask_buf = engine.upload_mask(&mask).unwrap();
    bench("train step (device-resident path)", budget, || {
        std::hint::black_box(
            engine.train_step_device(&mut state, &mask_buf, 1e-3, &dev_ep).unwrap(),
        );
    });
    bench("fwd: embed eval batch (device theta)", budget, || {
        std::hint::black_box(
            engine.embed_device(&state, engine.eval_batch(&padded)).unwrap().data[0],
        );
    });
}

fn main() {
    let smoke = std::env::args().any(|a| a == "smoke" || a == "--smoke");
    let sections = pure_rust_section(smoke);
    write_report(smoke, sections);
    pjrt_section(Duration::from_secs(if smoke { 1 } else { 3 }));
}
