//! `cargo bench` — hot-path microbenchmarks over the live PJRT
//! executables (the L3 §Perf targets in DESIGN.md): embedding forward,
//! fisher pass, masked train step, plus the pure-rust episode evaluator
//! and mask construction. Records the numbers EXPERIMENTS.md §Perf cites.

use std::time::Duration;

use tinytrain::coordinator::{episode_accuracy, ModelEngine};
use tinytrain::data::{domain_by_name, Sampler};
use tinytrain::model::ParamStore;
use tinytrain::runtime::{ArtifactStore, Runtime};
use tinytrain::util::bench::bench;
use tinytrain::util::rng::Rng;

fn main() {
    let budget = Duration::from_secs(3);
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("bench_hotpath: PJRT runtime unavailable (stub xla backend) — skipping");
        return;
    };
    let store = ArtifactStore::discover(None).expect("run `make artifacts`");
    let engine = ModelEngine::load(&rt, &store, "mcunet").expect("engine");
    let meta = &engine.meta;
    let mut params = ParamStore::init(meta, 1);

    let domain = domain_by_name("traffic").unwrap();
    let mut rng = Rng::new(5);
    let ep = Sampler::new(domain.as_ref(), &meta.shapes).sample(&mut rng);
    let padded = ep.pad(&meta.shapes);
    let pseudo = ep.pseudo_query(&meta.shapes, &mut rng);
    let mask = vec![1.0f32; meta.total_theta];

    println!(
        "-- PJRT hot path (mcunet scaled, EVAL_BATCH={}) --",
        meta.shapes.eval_batch
    );
    // warm-up: compile outside the timed regions
    let emb = engine.embed_with(&params, engine.eval_batch(&padded)).unwrap();
    engine.fisher_pass(&params, &padded, &pseudo).unwrap();
    engine
        .train_step(&mut params.clone(), &mask, 1e-3, &padded, &pseudo)
        .unwrap();

    bench("fwd: embed 80 images", budget, || {
        std::hint::black_box(
            engine.embed_with(&params, engine.eval_batch(&padded)).unwrap().data[0],
        );
    });
    bench("fisher pass (support+pseudo-query)", budget, || {
        std::hint::black_box(engine.fisher_pass(&params, &padded, &pseudo).unwrap().loss);
    });
    bench("train step (host round-trip path)", budget, || {
        std::hint::black_box(
            engine.train_step(&mut params, &mask, 1e-3, &padded, &pseudo).unwrap(),
        );
    });

    // Device-resident path (§Perf optimisation): theta/m/v stay on device.
    let mut state = engine.upload_state(&params).unwrap();
    let dev_ep = engine.upload_episode(&padded, &pseudo).unwrap();
    let mask_buf = engine.upload_mask(&mask).unwrap();
    bench("train step (device-resident path)", budget, || {
        std::hint::black_box(
            engine.train_step_device(&mut state, &mask_buf, 1e-3, &dev_ep).unwrap(),
        );
    });
    bench("fwd: embed 80 images (device theta)", budget, || {
        std::hint::black_box(
            engine.embed_device(&state, engine.eval_batch(&padded)).unwrap().data[0],
        );
    });

    println!("-- pure-rust episode path --");
    bench("evaluator: prototypes + cosine top-1", Duration::from_millis(300), || {
        std::hint::black_box(episode_accuracy(&emb.data, &padded, &meta.shapes));
    });
    bench("episode: sample + pad + pseudo-query", Duration::from_millis(500), || {
        let mut r = Rng::new(9);
        let e = Sampler::new(domain.as_ref(), &meta.shapes).sample(&mut r);
        let p = e.pad(&meta.shapes);
        std::hint::black_box((p.sup_x[0], e.pseudo_query(&meta.shapes, &mut r).x[0]));
    });
}
