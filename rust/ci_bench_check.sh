#!/usr/bin/env bash
# CI bench-regression guard (non-required job; see .github/workflows/ci.yml).
#
# Compares the freshly written BENCH_hotpath.json (produced by the
# bench_hotpath smoke tier — run `rust/ci.sh` or
# `cargo bench --bench bench_hotpath -- smoke` first) against the copy
# committed at HEAD, and fails when any section's `speedup` regressed by
# more than 25%. Sections present in only one of the two files — or
# malformed in either (non-object section, missing/non-numeric
# `speedup`) — are warned about and skipped, never failed: new benches
# land before their baseline is committed, and a half-written report
# should flag itself without masquerading as a perf regression. A
# baseline that does not parse as JSON at all skips the whole
# comparison with a notice. Timing noise is why this job is advisory:
# shared CI runners jitter far more than a laptop, so the guard flags
# rather than blocks.
#
# Usage: ci_bench_check.sh [threshold]   (default 0.25)
set -euo pipefail
cd "$(dirname "$0")/.."

THRESHOLD="${1:-0.25}"
FRESH="BENCH_hotpath.json"

if [ ! -f "$FRESH" ]; then
    echo "ci_bench_check: $FRESH not found — run rust/ci.sh (or the bench smoke tier) first" >&2
    exit 1
fi

if ! BASELINE_JSON=$(git show "HEAD:BENCH_hotpath.json" 2>/dev/null); then
    echo "ci_bench_check: no committed BENCH_hotpath.json at HEAD — nothing to compare, skipping"
    exit 0
fi

BASELINE_JSON="$BASELINE_JSON" FRESH_PATH="$FRESH" THRESHOLD="$THRESHOLD" python3 - <<'EOF'
import json
import os
import sys

threshold = float(os.environ["THRESHOLD"])
try:
    baseline = json.loads(os.environ["BASELINE_JSON"])
except ValueError as e:
    print(f"ci_bench_check: committed baseline is not valid JSON ({e}) — skipping comparison")
    sys.exit(0)
try:
    with open(os.environ["FRESH_PATH"]) as f:
        fresh = json.load(f)
except ValueError as e:
    print(f"ci_bench_check: fresh report is not valid JSON ({e}) — skipping comparison")
    sys.exit(0)

def speedups(report, label):
    """name -> speedup for well-formed sections; warn-and-skip the rest."""
    out = {}
    sections = report.get("sections") if isinstance(report, dict) else None
    if not isinstance(sections, dict):
        print(f"  ({label}) report has no 'sections' object — nothing to compare from it")
        return out
    for name, section in sections.items():
        if not isinstance(section, dict) or "speedup" not in section:
            continue  # scalar metadata entries (arch, layers, *_us) are expected
        try:
            out[name] = float(section["speedup"])
        except (TypeError, ValueError):
            print(f"  {name:<20} malformed speedup in {label} — skipped")
    return out

base, new = speedups(baseline, "baseline"), speedups(fresh, "fresh")
failures = []
for name in sorted(base.keys() | new.keys()):
    if name not in base:
        print(f"  {name:<20} new section (no baseline) — fresh speedup {new[name]:.2f}x")
        continue
    if name not in new:
        print(f"  {name:<20} missing from fresh report (baseline {base[name]:.2f}x) — skipped")
        continue
    ratio = new[name] / base[name] if base[name] > 0 else 1.0
    mark = "OK "
    if ratio < 1.0 - threshold:
        mark = "REG"
        failures.append(name)
    print(f"  {name:<20} {mark} baseline {base[name]:8.2f}x -> fresh {new[name]:8.2f}x "
          f"({(ratio - 1.0) * 100:+.1f}%)")

if failures:
    print(f"ci_bench_check: speedup regressed >{threshold:.0%} in: {', '.join(failures)}",
          file=sys.stderr)
    sys.exit(1)
print(f"ci_bench_check: no section regressed >{threshold:.0%}")
EOF
