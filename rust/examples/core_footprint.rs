//! MCU-envelope footprint artifact.
//!
//! Links the entire `no_std + alloc` decision core — cost accounting,
//! budgeted layer/channel selection, segment masks, the SparseUpdate
//! genome/feasibility machinery and the analytic masked step/embed math
//! — and nothing host-side. `rust/ci_size_check.sh` builds this target
//! with `--no-default-features --features alloc --profile embedded` and
//! records its per-section sizes in `SIZE_core.json`; the printed
//! checksums keep every subsystem reachable so the linker cannot discard
//! the code being measured.
//!
//! The binary itself is hosted (it prints via std, which is always
//! available to example crates), but the `tinytrain` library underneath
//! is compiled without its `std` feature — exactly the code an MCU
//! deployment would carry.

use tinytrain::accounting::{backward_macs, backward_memory, CostLedger, Optimizer, UpdatePlan};
use tinytrain::coordinator::analytic::{
    accumulate_rows, masked_shrink_step, masked_shrink_step_scalar, EmbedState,
};
use tinytrain::coordinator::criterion::Criterion;
use tinytrain::coordinator::search::{
    default_policy, genome_to_policy, mutate, random_feasible, resolve_budget, FeasibilityOracle,
};
use tinytrain::coordinator::selection::run_selection;
use tinytrain::coordinator::{Budgets, ChannelScheme};
use tinytrain::model::{ModelMeta, ParamStore};
use tinytrain::util::rng::Rng;

fn checksum(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| x as f64).sum()
}

fn main() {
    let meta = ModelMeta::synthetic(6);
    let mut rng = Rng::new(0xC0DE);
    let theta: Vec<f32> = (0..meta.total_theta).map(|_| rng.range(-0.5, 0.5) as f32).collect();
    let params = ParamStore::from_theta(&meta, theta);

    // Accounting: incremental ledger walk + closed-form plan pricing.
    let mut ledger = CostLedger::new(&meta.scaled, Optimizer::Adam);
    for l in (0..meta.scaled.layers.len()).step_by(3) {
        ledger.set_ratio(l, 0.25);
    }
    let ledger_mem = ledger.memory_total();
    let ledger_macs = ledger.macs_total();
    let plan = UpdatePlan::adapter_drop(meta.scaled.layers.len(), meta.scaled.blocks.len(), 0.5);
    let plan_mem = backward_memory(&meta.scaled, &plan, Optimizer::Adam).total();
    let plan_macs = backward_macs(&meta.scaled, &plan).total();

    // Selection: Algorithm-1 layer/channel picks and the segment mask.
    let sel = run_selection(
        &meta,
        Criterion::L2Norm,
        None,
        &params.theta,
        Budgets::default(),
        0.5,
        ChannelScheme::L2Norm,
        Optimizer::Adam,
    );
    let mask = sel.mask(&meta);

    // SparseUpdate policy machinery: on-device feasibility check/repair.
    let policy = default_policy(&meta, 0.0);
    let budget = resolve_budget(&meta, 0.0);
    let mut oracle = FeasibilityOracle::new(&meta, budget);
    let genome = random_feasible(&mut oracle, &mut rng).expect("budget admits a genome");
    let child = mutate(&mut oracle, &genome, &mut rng);
    let repaired = genome_to_policy(&child);

    // Analytic masked steps + embed over the selected mask.
    let s = &meta.shapes;
    let img_len = s.img * s.img * s.channels;
    let sup: Vec<f32> = (0..s.max_support * img_len).map(|_| rng.range(-1.0, 1.0) as f32).collect();
    let qry: Vec<f32> = (0..s.max_query * img_len).map(|_| rng.range(-1.0, 1.0) as f32).collect();
    let mut overlay: Vec<Vec<f32>> = mask
        .runs()
        .iter()
        .map(|&(off, len)| params.theta[off..off + len].to_vec())
        .collect();
    let mut st = EmbedState::build(s, meta.total_theta, |t| params.theta[t], &sup, &qry);
    st.refresh_plan(Some(&mask), &sup, &qry);
    for _ in 0..4 {
        masked_shrink_step(&mask, &mut overlay, Some(&mut st), s, &sup, &qry, 0.05);
    }
    // One step through the scalar reference arm keeps it linked (and
    // measured) alongside the planned kernels — it is the asserted
    // baseline in tests and the bench.
    masked_shrink_step_scalar(&mask, &mut overlay, Some(&mut st), s, &sup, &qry, 0.05);
    st.rebuild_if_dirty(&sup, &qry);
    let emb = st.normalized(s.feat_dim);
    let mut raw_ref = vec![0.0f32; emb.len()];
    let sup_rows = s.max_support * s.feat_dim;
    accumulate_rows(&sup, img_len, &st.proj, s.feat_dim, &mut raw_ref[..sup_rows]);
    accumulate_rows(&qry, img_len, &st.proj, s.feat_dim, &mut raw_ref[sup_rows..]);

    println!("arch {} theta {} mask_nnz {}", meta.arch, meta.total_theta, mask.nnz());
    println!("ledger mem {ledger_mem:.1} macs {ledger_macs:.1}");
    println!("plan mem {plan_mem:.1} macs {plan_macs:.1}");
    println!("selected layers {} policy {} repaired {}", sel.layers.len(),
        policy.layer_ratios.len(), repaired.layer_ratios.len());
    println!("embed checksum {:.6} incremental {}", checksum(&emb), st.incremental);
    println!("accumulate_ref checksum {:.6}", checksum(&raw_ref));
    let overlay_sum: f64 = overlay.iter().map(|seg| checksum(seg.as_slice())).sum();
    println!("overlay checksum {overlay_sum:.6}");
    // int8 delta codec (util::quant) — part of the MCU core: flash-
    // resident deltas reuse the serving tier's exact encoder.
    let q = tinytrain::util::quant::quantize_run(&emb);
    let dq = tinytrain::util::quant::dequantize_run(&q);
    println!("quant scale {:e} checksum {:.6}", q.scale, checksum(&dq));
}
