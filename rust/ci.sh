#!/usr/bin/env bash
# Tier-1 verification for the rust workspace (wired into README/ROADMAP):
#   fmt -> clippy (warnings are errors) -> release build -> tests
#   -> no_std feature matrix (build + clippy + bit-identity tests under
#      --no-default-features --features alloc; since PR 9 this also
#      gates the blocked-SIMD kernels in coordinator::kernels — the
#      ragged-shape scalar-vs-blocked tests run in both feature sets)
#   -> net loopback smoke (ci_net_smoke.sh: serve --listen + loadgen,
#      wire results asserted bit-identical to the in-process arm)
#   -> chaos smoke (ci_chaos_smoke.sh: faulted replay across a server
#      restart, final deltas asserted bit-identical to fault-free)
#   -> bench_hotpath smoke (writes ../BENCH_hotpath.json)
#   -> size-budget gate (ci_size_check.sh; writes ../SIZE_core.json and
#      prints the per-section table).
# Run from anywhere; operates on the directory this script lives in.
#
# Usage: ci.sh [--quick]
#   --quick   fmt + clippy + `cargo test -q` only (debug profile); skips
#             the release build, the no_std matrix, the bench smoke and
#             the size gate. For inner-loop iteration — CI and pre-merge
#             runs use the full tier.
#
# PJRT-dependent integration tests self-skip when the workspace is built
# against the vendored stub `xla` backend, so this passes (and is
# meaningful) both with and without the real bindings/artifacts.
set -euo pipefail
cd "$(dirname "$0")"

QUICK=0
for arg in "$@"; do
    case "$arg" in
        --quick) QUICK=1 ;;
        *) echo "ci.sh: unknown argument '$arg' (usage: ci.sh [--quick])" >&2; exit 2 ;;
    esac
done

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install a Rust toolchain (>= 1.70)" >&2
    exit 1
fi

# Say up front which xla backend this build resolves: the vendored stub
# (PJRT paths error recoverably, integration tests self-skip) or real
# bindings (the live pipeline runs).
if grep -Eq '^xla *= *\{ *path *= *"vendor/xla"' Cargo.toml; then
    echo "== xla backend: vendored stub (rust/vendor/xla) — PJRT tests will self-skip =="
else
    echo "== xla backend: non-vendored (real PJRT bindings) — live pipeline enabled =="
fi

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy -D warnings =="
cargo clippy --all-targets -- -D warnings

if [ "$QUICK" = 1 ]; then
    echo "== cargo test -q (quick tier: debug profile) =="
    cargo test -q

    echo "ci.sh: quick tier green (release build + bench smoke skipped)"
    exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# no_std feature matrix: the MCU decision core must build, lint clean,
# and produce bit-identical arithmetic without the std feature. The
# default-features leg of the matrix is already covered above (the
# no_std_core test runs as part of plain `cargo test`).
echo "== no_std core: build (--no-default-features --features alloc) =="
cargo build --lib --example core_footprint --no-default-features --features alloc

echo "== no_std core: clippy -D warnings =="
cargo clippy --lib --example core_footprint --no-default-features --features alloc -- -D warnings

echo "== no_std core: bit-identity tests =="
cargo test -q --no-default-features --features alloc --test no_std_core

echo "== net loopback smoke (serve --listen + loadgen wire bit-identity) =="
./ci_net_smoke.sh --prebuilt

echo "== chaos smoke (fault injection + snapshot restart bit-identity) =="
./ci_chaos_smoke.sh --prebuilt

echo "== bench_hotpath smoke (pure-rust; writes ../BENCH_hotpath.json) =="
cargo bench --bench bench_hotpath -- smoke

echo "== size-budget gate (embedded profile; writes ../SIZE_core.json) =="
./ci_size_check.sh

echo "ci.sh: all green"
