#!/usr/bin/env bash
# Tier-1 verification for the rust workspace (wired into README/ROADMAP):
#   fmt -> clippy (warnings are errors) -> release build -> tests.
# Run from anywhere; operates on the directory this script lives in.
# PJRT-dependent integration tests self-skip when the workspace is built
# against the vendored stub `xla` backend, so this passes (and is
# meaningful) both with and without the real bindings/artifacts.
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install a Rust toolchain (>= 1.70)" >&2
    exit 1
fi

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== bench_hotpath smoke (pure-rust; writes ../BENCH_hotpath.json) =="
cargo bench --bench bench_hotpath -- smoke

echo "ci.sh: all green"
