//! Offline stand-in for the `anyhow` crate, covering the subset this
//! workspace uses: `Result`, `Error`, `anyhow!`, `ensure!`, `bail!` and
//! `Context::{context, with_context}` with a `:#` chain display. The
//! API mirrors the real crate so swapping the path dependency for the
//! crates.io release is a no-op.

use std::fmt;

/// Error type: a message plus an optional boxed cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
    context: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None, context: Vec::new() }
    }

    fn push_context(mut self, ctx: String) -> Error {
        self.context.push(ctx);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first (matches anyhow's ordering).
        if let Some(ctx) = self.context.last() {
            write!(f, "{ctx}")?;
        } else {
            write!(f, "{}", self.msg)?;
        }
        if f.alternate() {
            // `{:#}` renders the whole chain inline. `msg` already holds
            // the root cause's display, so only walk deeper sources.
            for ctx in self.context.iter().rev().skip(1) {
                write!(f, ": {ctx}")?;
            }
            if !self.context.is_empty() {
                write!(f, ": {}", self.msg)?;
            }
            let mut src = self.source.as_ref().and_then(|s| s.source());
            while let Some(s) = src {
                write!(f, ": {s}")?;
                src = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)), context: Vec::new() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(f().to_string()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.push_context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = anyhow!("top {}", 3);
        assert_eq!(format!("{e}"), "top 3");
        let e: Result<(), _> = Err(io_err());
        let e = e.with_context(|| "loading weights").unwrap_err();
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: gone");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(11).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
