//! Offline stand-in for the `anyhow` crate, covering the subset this
//! workspace uses: `Result`, `Error`, `anyhow!`, `ensure!`, `bail!` and
//! `Context::{context, with_context}` with a `:#` chain display. The
//! API mirrors the real crate so swapping the path dependency for the
//! crates.io release is a no-op.
//!
//! Mirroring the real crate's feature surface, `std` is default-on and
//! disabling it yields a `no_std + alloc` build. The no_std build keeps
//! message + context semantics but drops the boxed source chain and the
//! blanket `From<E: std::error::Error>` impl (`core::error::Error` is
//! not stable on the pinned 1.79 toolchain); no_std callers construct
//! errors via the macros or `Error::msg`, which is exactly what the
//! gated decision core of `tinytrain` does.

#![cfg_attr(not(feature = "std"), no_std)]

extern crate alloc;

#[cfg(feature = "std")]
use alloc::boxed::Box;
use alloc::string::{String, ToString};
use alloc::vec::Vec;
use core::fmt;

// Macro plumbing: `$crate::__private::format!` resolves in consumer
// crates whether or not they themselves link `alloc` by that name.
#[doc(hidden)]
pub mod __private {
    pub use alloc::format;
}

/// Error type: a message plus an optional boxed cause chain (the chain
/// exists only with `std`, where `std::error::Error` is available).
pub struct Error {
    msg: String,
    #[cfg(feature = "std")]
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
    context: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            msg: msg.to_string(),
            #[cfg(feature = "std")]
            source: None,
            context: Vec::new(),
        }
    }

    fn push_context(mut self, ctx: String) -> Error {
        self.context.push(ctx);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost context first (matches anyhow's ordering).
        if let Some(ctx) = self.context.last() {
            write!(f, "{ctx}")?;
        } else {
            write!(f, "{}", self.msg)?;
        }
        if f.alternate() {
            // `{:#}` renders the whole chain inline. `msg` already holds
            // the root cause's display, so only walk deeper sources.
            for ctx in self.context.iter().rev().skip(1) {
                write!(f, ": {ctx}")?;
            }
            if !self.context.is_empty() {
                write!(f, ": {}", self.msg)?;
            }
            #[cfg(feature = "std")]
            {
                let mut src = self.source.as_ref().and_then(|s| s.source());
                while let Some(s) = src {
                    write!(f, ": {s}")?;
                    src = s.source();
                }
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

#[cfg(feature = "std")]
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)), context: Vec::new() }
    }
}

pub type Result<T, E = Error> = core::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

#[cfg(feature = "std")]
impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(f().to_string()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.push_context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg($crate::__private::format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "std")]
    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    #[cfg(feature = "std")]
    fn display_plain_and_alternate() {
        let e: Error = anyhow!("top {}", 3);
        assert_eq!(format!("{e}"), "top 3");
        let e: Result<(), _> = Err(io_err());
        let e = e.with_context(|| "loading weights").unwrap_err();
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: gone");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(11).is_err());
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let e: Result<()> = Err(anyhow!("root"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    #[cfg(feature = "std")]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
