//! Stub of the `xla` (xla-rs) PJRT bindings used by `tinytrain::runtime`.
//!
//! The offline image bakes no `libxla`, so this crate keeps the workspace
//! compiling and makes the *absence* of the runtime a recoverable error
//! instead of a link failure:
//!
//! - Host-side [`Literal`] construction/reshape/readback is implemented
//!   for real (f32 only — the only dtype the coordinator exchanges), so
//!   `Tensor` round-trips and their tests work without PJRT.
//! - Anything that needs a live PJRT client ([`PjRtClient::cpu`],
//!   compilation, buffer transfer, execution) returns [`Error`] with a
//!   `PJRT_UNAVAILABLE` message. `tinytrain` surfaces that error and the
//!   integration tests self-skip on it; the `AnalyticBackend` episode
//!   path never reaches this crate at all.
//!
//! Replacing the `xla` path dependency in `rust/Cargo.toml` with the real
//! bindings restores the live paths without further code changes.

use std::fmt;
use std::path::Path;

const PJRT_UNAVAILABLE: &str =
    "PJRT unavailable: built against the bundled xla stub (no libxla in this environment)";

/// Error type mirroring xla-rs's (Display + std::error::Error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(PJRT_UNAVAILABLE.to_string()))
}

/// Marker for element types the stub can materialise host-side.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
    fn to_f32(self) -> f32 {
        self
    }
}

/// Host-side dense f32 literal (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// Shape of a (non-tuple) literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Flatten a tuple literal. The stub never produces tuples (they only
    /// come back from executions, which require PJRT).
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }
}

/// Device-resident buffer handle. Never constructed by the stub.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Compiled executable handle. Never constructed by the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// PJRT client handle: every constructor reports the stub.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

/// Parsed HLO module. The stub cannot parse HLO text.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Computation wrapper (proto -> compilable form).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT unavailable"));
    }
}
