#!/usr/bin/env bash
# CI size-budget gate for the MCU-envelope core (see README "MCU
# envelope" and .github/workflows/ci.yml).
#
# Builds the `core_footprint` example — the link target that pulls in
# exactly the no_std + alloc decision core — under the `embedded`
# release profile (opt-level=z, lto, panic=abort), measures its ELF
# section sizes, writes ../SIZE_core.json (the same `sections` table
# shape BENCH_hotpath.json uses), and compares against the copy
# committed at HEAD: flash (text + rodata + data) growing by more than
# the threshold fails the check. Like ci_bench_check.sh, a missing
# committed baseline skips the comparison with a notice — the first run
# on a branch produces the baseline to commit.
#
# Usage: ci_size_check.sh [threshold]   (default 0.10 = 10% flash growth)
set -euo pipefail
cd "$(dirname "$0")"

THRESHOLD="${1:-0.10}"
OUT="../SIZE_core.json"
BIN="target/embedded/examples/core_footprint"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci_size_check: cargo not found on PATH — install a Rust toolchain" >&2
    exit 1
fi

echo "== build core_footprint (embedded profile, no_std + alloc core) =="
cargo build --profile embedded --no-default-features --features alloc --example core_footprint

if [ ! -f "$BIN" ]; then
    echo "ci_size_check: expected artifact $BIN not found after build" >&2
    exit 1
fi

# Per-section sizes from the ELF section headers directly (python
# stdlib only — no binutils dependency). Classification follows the
# usual MCU budget split:
#   text   = alloc + exec            (flash: code)
#   rodata = alloc, read-only data   (flash: constants)
#   data   = alloc + write, w/ bits  (flash image + ram at runtime)
#   bss    = alloc NOBITS            (ram only)
BIN_PATH="$BIN" OUT_PATH="$OUT" THRESHOLD="$THRESHOLD" \
BASELINE_JSON="$(git show "HEAD:SIZE_core.json" 2>/dev/null || true)" \
python3 - <<'EOF'
import json
import os
import struct
import sys

path = os.environ["BIN_PATH"]
with open(path, "rb") as f:
    elf = f.read()

if elf[:4] != b"\x7fELF" or elf[4] != 2:
    sys.exit(f"ci_size_check: {path} is not a 64-bit ELF")

e_shoff, = struct.unpack_from("<Q", elf, 0x28)
e_shentsize, e_shnum = struct.unpack_from("<HH", elf, 0x3A)

SHT_NOBITS = 8
SHF_WRITE, SHF_ALLOC, SHF_EXECINSTR = 0x1, 0x2, 0x4

sizes = {"text": 0, "rodata": 0, "data": 0, "bss": 0}
for i in range(e_shnum):
    off = e_shoff + i * e_shentsize
    sh_type, = struct.unpack_from("<I", elf, off + 4)
    sh_flags, sh_addr, sh_off, sh_size = struct.unpack_from("<QQQQ", elf, off + 8)
    if not sh_flags & SHF_ALLOC or sh_size == 0:
        continue
    if sh_type == SHT_NOBITS:
        sizes["bss"] += sh_size
    elif sh_flags & SHF_EXECINSTR:
        sizes["text"] += sh_size
    elif sh_flags & SHF_WRITE:
        sizes["data"] += sh_size
    else:
        sizes["rodata"] += sh_size

sizes["flash"] = sizes["text"] + sizes["rodata"] + sizes["data"]
sizes["ram"] = sizes["data"] + sizes["bss"]

report = {
    "generated_by": "rust/ci_size_check.sh",
    "artifact": "core_footprint (embedded profile, --no-default-features --features alloc)",
    "sections": {name: {"bytes": n} for name, n in sizes.items()},
}
with open(os.environ["OUT_PATH"], "w") as f:
    json.dump(report, f, indent=2)
    f.write("\n")

baseline_raw = os.environ.get("BASELINE_JSON", "").strip()
baseline = None
if baseline_raw:
    try:
        baseline = json.loads(baseline_raw)
    except ValueError:
        print("ci_size_check: committed SIZE_core.json is malformed — comparison skipped")

def baseline_bytes(name):
    try:
        return int(baseline["sections"][name]["bytes"])
    except (KeyError, TypeError, ValueError):
        return None

print(f"{'section':<8} {'bytes':>10}  baseline  delta")
threshold = float(os.environ["THRESHOLD"])
failures = []
for name in ("text", "rodata", "data", "bss", "flash", "ram"):
    n = sizes[name]
    b = baseline_bytes(name) if baseline else None
    if b is None:
        print(f"{name:<8} {n:>10}  (no baseline)")
        continue
    delta = (n - b) / b if b else 0.0
    mark = ""
    if name == "flash" and delta > threshold:
        mark = "  REGRESSION"
        failures.append(name)
    print(f"{name:<8} {n:>10}  {b:>8}  {delta:+7.1%}{mark}")

if baseline is None:
    print("ci_size_check: no committed SIZE_core.json at HEAD — baseline "
          "written, comparison skipped (commit SIZE_core.json to arm the gate)")
elif failures:
    print(f"ci_size_check: flash grew >{threshold:.0%} over the committed baseline",
          file=sys.stderr)
    sys.exit(1)
else:
    print(f"ci_size_check: flash within {threshold:.0%} of the committed baseline")
EOF
